//! Checkpoint/resume end-to-end tests (host engine, no artifacts):
//! the kill-and-resume parity contract — a server killed after a
//! durable checkpoint and resumed from disk must continue the *exact*
//! learner trajectory an uninterrupted run would have produced
//! (bit-identical β values, bit-identical train/calib chunk counts,
//! cumulative serve counters) — plus the cadence-checkpoint barrier
//! and the cumulative-report semantics of a resumed run.
//!
//! Corrupt-checkpoint handling (truncated file, bad version, missing
//! shard entry, topology mismatch) is unit-tested in `serve::ckpt`.

use std::path::PathBuf;
use std::sync::mpsc::channel;
use std::time::Duration;

use ocl::config::{BenchmarkId, CascadeConfig, ExpertId, ServeConfig};
use ocl::data::Benchmark;
use ocl::serve::ckpt::{self, CkptOptions, CkptSink, ResumeMode};
use ocl::serve::shard::ShardFront;
use ocl::serve::{load, Request, Response, ServeReport, Server};
use ocl::sim::{Expert, ExpertProfile};

fn expert_for(b: &Benchmark, seed: u64) -> Expert {
    let mean_len =
        b.samples.iter().map(|s| s.len as f64).sum::<f64>() / b.samples.len() as f64;
    Expert::new(
        ExpertProfile::for_pair(ExpertId::Gpt35, BenchmarkId::Imdb),
        b.strata_fractions(),
        mean_len,
        seed,
    )
}

/// Never sheds, no cadence checkpoints (graceful-shutdown one only).
fn unbounded() -> ServeConfig {
    ServeConfig::builder().max_pending(1 << 16).ckpt_every(0).build().unwrap()
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("ocl-ckpt-e2e-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Serve samples `lo..hi` (original stream ids) through `server`,
/// returning the report and the responses.
fn run_range(
    server: Server,
    b: &Benchmark,
    lo: usize,
    hi: usize,
) -> (ServeReport, Vec<Response>) {
    let (req_tx, req_rx) = channel();
    let (resp_tx, resp_rx) = channel();
    let samples: Vec<_> = b.samples[lo..hi].to_vec();
    let submit = std::thread::spawn(move || {
        for (k, s) in samples.iter().enumerate() {
            if req_tx
                .send(Request {
                    id: (lo + k) as u64,
                    text: s.text.clone(),
                    truth: s.label,
                    sample: s.clone(),
                })
                .is_err()
            {
                break;
            }
        }
    });
    let report = server.serve(req_rx, resp_tx).expect("serve");
    submit.join().unwrap();
    (report, resp_rx.iter().collect())
}

#[test]
fn kill_and_resume_beta_trajectory_is_bit_identical() {
    // The tentpole acceptance: run K requests with durability on, kill
    // the process (drop the server — its in-memory state is gone),
    // restore from disk, serve the remaining N−K, and the final β
    // vector must be bit-for-bit what one uninterrupted N-request run
    // produces. β decays once per admitted request with each level's
    // own factor, so any restore defect (lost decay state, replayed
    // admissions, wrong cursor) shifts the trajectory.
    let n = 300;
    let k = 140;
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 35, n);
    let cfg = {
        let mut c = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        c.seed = 35;
        c
    };

    // Uninterrupted reference.
    let reference =
        Server::new(cfg.clone(), b.classes, expert_for(&b, 35), unbounded(), "artifacts")
            .unwrap();
    let (ref_report, ref_responses) = run_range(reference, &b, 0, n);
    assert_eq!(ref_report.served, n);
    assert_eq!(ref_responses.len(), n);

    // Interrupted run: first K requests, graceful drain writes the
    // shutdown checkpoint, then the process "dies" (server dropped).
    let dir = tmpdir("beta");
    let sink = CkptSink::create(&dir, 1).unwrap();
    let mut srv1 =
        Server::new(cfg.clone(), b.classes, expert_for(&b, 35), unbounded(), "artifacts")
            .unwrap();
    srv1.attach_ckpt(sink, 0);
    let (report1, _) = run_range(srv1, &b, 0, k);
    assert_eq!(report1.served, k);
    assert_eq!(report1.ckpts, 1, "graceful shutdown must write one checkpoint");

    // Resume from disk and serve the tail.
    let mut states = ckpt::load_latest(&dir, ResumeMode::Strict, 1)
        .unwrap()
        .expect("checkpoint present");
    let state = states.remove(0);
    assert_eq!(state.cursor, k as u64, "quiescent cursor covers the served prefix");
    let srv2 = Server::resume(
        cfg.clone(),
        b.classes,
        expert_for(&b, 35),
        unbounded(),
        "artifacts",
        state,
    )
    .unwrap();
    let (report2, responses2) = run_range(srv2, &b, k, n);
    assert!(report2.resumed, "resumed run must say so");
    assert_eq!(responses2.len(), n - k, "only the tail is re-served");
    assert_eq!(report2.served, n, "cumulative counters continue the first run");
    assert_eq!(
        report2.handled.iter().sum::<usize>(),
        n,
        "cumulative handled mix covers the whole stream"
    );
    assert_eq!(report2.final_betas.len(), ref_report.final_betas.len());
    for (i, (got, want)) in report2
        .final_betas
        .iter()
        .zip(&ref_report.final_betas)
        .enumerate()
    {
        assert_eq!(
            got.to_bits(),
            want.to_bits(),
            "level {i} β must be bit-identical: resumed {got} vs uninterrupted {want}"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn kill_and_resume_train_chunk_counts_match_uninterrupted() {
    // Chunk-count half of the parity contract, under the same forced
    // expert regime the Cascade-parity test uses (β ≡ 1, no decay:
    // every request is annotated, so the training cadence is fully
    // determined by the annotation count and the restored trigger
    // counters). Restoring caches + `pendings` + chunk counters means
    // the resumed run's cumulative train/calib chunk counts must land
    // exactly on the uninterrupted run's.
    let n = 240;
    let k = 120;
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 41, n);
    let cfg = {
        let mut c = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        c.seed = 41;
        c.beta0 = 1.0;
        for l in &mut c.levels {
            l.beta_decay = 1.0;
        }
        c
    };

    let reference =
        Server::new(cfg.clone(), b.classes, expert_for(&b, 5), unbounded(), "artifacts")
            .unwrap();
    let (ref_report, _) = run_range(reference, &b, 0, n);
    assert!(
        ref_report.train_batches.iter().all(|&t| t > 0),
        "reference must actually train: {:?}",
        ref_report.train_batches
    );

    let dir = tmpdir("chunks");
    let sink = CkptSink::create(&dir, 1).unwrap();
    let mut srv1 =
        Server::new(cfg.clone(), b.classes, expert_for(&b, 5), unbounded(), "artifacts")
            .unwrap();
    srv1.attach_ckpt(sink, 0);
    let (report1, _) = run_range(srv1, &b, 0, k);
    assert_eq!(report1.handled[cfg.levels.len()], k, "β ≡ 1: all to the expert");

    let mut states =
        ckpt::load_latest(&dir, ResumeMode::Strict, 1).unwrap().expect("ckpt");
    let srv2 = Server::resume(
        cfg.clone(),
        b.classes,
        expert_for(&b, 5),
        unbounded(),
        "artifacts",
        states.remove(0),
    )
    .unwrap();
    let (report2, _) = run_range(srv2, &b, k, n);
    assert_eq!(
        report2.train_batches, ref_report.train_batches,
        "cumulative model chunk counts must be bit-identical to uninterrupted"
    );
    assert_eq!(
        report2.calib_batches, ref_report.calib_batches,
        "cumulative calibrator chunk counts must be bit-identical to uninterrupted"
    );
    assert_eq!(report2.llm_calls, ref_report.llm_calls, "expert-call totals too");
    assert_eq!(report2.served, n);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_config_drift_errors_strict_and_falls_back_best_effort() {
    // A checkpoint taken under the 2-level small cascade must not be
    // restored into a 3-level large cascade: strict resume errors
    // cleanly; best-effort falls back to a fresh start — the same
    // policy as every other checkpoint defect.
    let n = 80;
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 61, n);
    let cfg = {
        let mut c = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        c.seed = 61;
        c
    };
    let dir = tmpdir("drift");
    let sink = CkptSink::create(&dir, 1).unwrap();
    let mut srv =
        Server::new(cfg, b.classes, expert_for(&b, 61), unbounded(), "artifacts")
            .unwrap();
    srv.attach_ckpt(sink, 0);
    let (report, _) = run_range(srv, &b, 0, n);
    assert_eq!(report.ckpts, 1);

    let large = CascadeConfig::large(BenchmarkId::Imdb, ExpertId::Gpt35);
    let dir_s = dir.to_string_lossy().to_string();
    let err = ShardFront::with_ckpt(
        large.clone(),
        b.classes,
        expert_for(&b, 61),
        unbounded(),
        "artifacts",
        Some(CkptOptions { dir: dir_s.clone(), resume: Some(ResumeMode::Strict) }),
    )
    .unwrap_err();
    assert!(
        err.to_string().contains("checkpoint"),
        "shape drift must be a clean checkpoint error: {err}"
    );
    let front = ShardFront::with_ckpt(
        large,
        b.classes,
        expert_for(&b, 61),
        unbounded(),
        "artifacts",
        Some(CkptOptions { dir: dir_s, resume: Some(ResumeMode::BestEffort) }),
    )
    .unwrap();
    assert_eq!(front.resume_cursor(), 0, "best-effort drift → fresh start");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn slow_export_authority_never_stalls_admission() {
    // The checkpoint-barrier liveness regression: an authority that is
    // alive but too slow to export within `export_timeout` must ABORT
    // the cadence attempt (admission resumes, the next cadence re-arms)
    // — before the fix it was misread as "authority died", the barrier
    // stayed armed waiting for a respawn that never came, and the
    // stream wedged forever. `export_timeout = 0` makes "too slow"
    // deterministic: every cadence export expires before the perfectly
    // healthy authority can answer.
    let n = 300;
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 47, n);
    let cfg = {
        let mut c = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        c.seed = 47;
        c
    };
    let serve_cfg = ServeConfig::builder()
        .max_pending(1 << 16)
        .ckpt_every(16)
        .export_timeout(Duration::ZERO)
        .build()
        .unwrap();
    let dir = tmpdir("slow-export");
    let sink = CkptSink::create(&dir, 1).unwrap();
    let mut srv =
        Server::new(cfg.clone(), b.classes, expert_for(&b, 47), serve_cfg, "artifacts")
            .unwrap();
    srv.attach_ckpt(sink, 0);
    // Paced arrivals so cadence barriers trip while the stream is open
    // (same pacing rationale as the cadence-checkpoint test above).
    let (req_tx, req_rx) = channel();
    let (resp_tx, resp_rx) = channel();
    let submit = load::drive(
        b.samples.clone(),
        load::Arrival::Poisson { rate: 1500.0 },
        13,
        req_tx,
    );
    let report = srv
        .serve(req_rx, resp_tx)
        .expect("a live-but-slow authority must not kill the run");
    assert_eq!(submit.join().unwrap(), n, "pre-fix this run never finished");
    let responses: Vec<Response> = resp_rx.iter().collect();
    assert_eq!(responses.len(), n, "every request answered despite aborted ckpts");
    assert_eq!(report.served, n);
    assert!(
        report.ckpt_aborts >= 1,
        "a zero export budget must abort cadence attempts (got {})",
        report.ckpt_aborts
    );
    assert_eq!(
        report.ckpts, 1,
        "only the patient graceful-shutdown checkpoint lands"
    );
    assert_eq!(report.restarts, vec![0, 0], "no worker was wrongly declared dead");

    // The shutdown checkpoint is still a fully valid resume point.
    let mut states =
        ckpt::load_latest(&dir, ResumeMode::Strict, 1).unwrap().expect("shutdown ckpt");
    let state = states.remove(0);
    assert_eq!(state.cursor, n as u64);
    assert_eq!(state.served, n);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn cadence_checkpoints_fire_and_capture_quiescent_cursors() {
    // Mid-stream durability: with `ckpt_every` set, checkpoints land
    // during the run (each at a drained barrier), the newest one is
    // loadable, and a resume that serves nothing extra reproduces the
    // run's final state exactly.
    let n = 300;
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 53, n);
    let cfg = {
        let mut c = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        c.seed = 53;
        c
    };
    let serve_cfg =
        ServeConfig::builder().max_pending(1 << 16).ckpt_every(16).build().unwrap();
    let dir = tmpdir("cadence");
    let sink = CkptSink::create(&dir, 1).unwrap();
    let mut srv =
        Server::new(cfg.clone(), b.classes, expert_for(&b, 53), serve_cfg, "artifacts")
            .unwrap();
    srv.attach_ckpt(sink, 0);
    // Paced arrivals: a cadence checkpoint is a quiescent barrier, so
    // the stream must still be *open* when the annotation count trips
    // it — an unpaced blast closes the input before the first trigger.
    let (req_tx, req_rx) = channel();
    let (resp_tx, resp_rx) = channel();
    let submit = load::drive(
        b.samples.clone(),
        load::Arrival::Poisson { rate: 1500.0 },
        13,
        req_tx,
    );
    let report = srv.serve(req_rx, resp_tx).expect("serve");
    assert_eq!(submit.join().unwrap(), n);
    let responses: Vec<Response> = resp_rx.iter().collect();
    assert_eq!(responses.len(), n, "the barrier must not lose or duplicate answers");
    assert_eq!(report.served, n);
    assert!(
        report.ckpts >= 2,
        "cadence checkpoints must fire mid-stream (got {})",
        report.ckpts
    );

    let mut states =
        ckpt::load_latest(&dir, ResumeMode::Strict, 1).unwrap().expect("ckpt");
    let state = states.remove(0);
    assert_eq!(state.cursor, n as u64, "final checkpoint covers the whole stream");
    assert_eq!(state.served, n);

    // Resume with an already-empty stream: pure restore, no new work.
    let srv2 = Server::resume(
        cfg.clone(),
        b.classes,
        expert_for(&b, 53),
        serve_cfg,
        "artifacts",
        state,
    )
    .unwrap();
    let (report2, responses2) = run_range(srv2, &b, n, n);
    assert!(report2.resumed);
    assert!(responses2.is_empty());
    assert_eq!(report2.served, n, "restored cumulative counters");
    assert_eq!(report2.final_betas, report.final_betas, "β state restored exactly");
    assert_eq!(report2.train_batches, report.train_batches);
    assert_eq!(report2.calib_batches, report.calib_batches);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn barrier_mid_speculation_drains_and_resumes_bit_identical() {
    // Pipelining + speculation vs the checkpoint barrier: quiescence
    // now also drains the stage queues and any in-flight speculative
    // copies, so a barrier taken mid-speculation must neither wedge nor
    // leak state into the snapshot. Forced-defer config (β = 0 after
    // the first admission, every gate open) on the 4-level cascade
    // keeps speculative copies in flight almost continuously, and with
    // every request annotated, `ckpt_every = 8` trips a barrier every
    // 8 requests — dozens of mid-speculation barriers per run.
    let n = 280;
    let k = 130;
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 67, n);
    let cfg = {
        let mut c = CascadeConfig::large(BenchmarkId::Imdb, ExpertId::Gpt35);
        c.seed = 67;
        c.beta0 = 1.0;
        for l in &mut c.levels {
            l.beta_decay = 0.0; // β = 0 after the first admission: no jumps
            l.calibration = 0.0; // untrained gates always defer
        }
        c
    };
    let spec_cfg = ServeConfig::builder()
        .max_pending(1 << 16)
        .ckpt_every(8)
        .pipeline(true)
        .spec_threshold(1e-6) // aggressive: any positive score speculates
        .build()
        .unwrap();

    // Uninterrupted paced run: cadence barriers trip while speculative
    // work is in flight, and every request is still answered once.
    let dir = tmpdir("spec");
    let sink = CkptSink::create(&dir, 1).unwrap();
    let mut srv =
        Server::new(cfg.clone(), b.classes, expert_for(&b, 67), spec_cfg, "artifacts")
            .unwrap();
    srv.attach_ckpt(sink, 0);
    let (req_tx, req_rx) = channel();
    let (resp_tx, resp_rx) = channel();
    let submit = load::drive(
        b.samples.clone(),
        load::Arrival::Poisson { rate: 1500.0 },
        13,
        req_tx,
    );
    let report = srv.serve(req_rx, resp_tx).expect("serve");
    assert_eq!(submit.join().unwrap(), n);
    let responses: Vec<Response> = resp_rx.iter().collect();
    assert_eq!(responses.len(), n, "barriers must not lose or duplicate answers");
    assert_eq!(report.served, n);
    assert!(
        report.ckpts >= 2,
        "cadence barriers must fire mid-stream (got {})",
        report.ckpts
    );
    assert!(
        report.spec_hits > 0,
        "speculation must be live while barriers fire: hits={} wasted={}",
        report.spec_hits,
        report.spec_wasted
    );

    // Kill after K requests — the graceful-shutdown barrier drains the
    // in-flight speculative work into a quiescent snapshot — then
    // resume and finish: bit-identical to the uninterrupted run.
    let dir2 = tmpdir("spec-resume");
    let sink2 = CkptSink::create(&dir2, 1).unwrap();
    let mut srv1 =
        Server::new(cfg.clone(), b.classes, expert_for(&b, 67), spec_cfg, "artifacts")
            .unwrap();
    srv1.attach_ckpt(sink2, 0);
    let (report1, _) = run_range(srv1, &b, 0, k);
    assert!(report1.spec_hits > 0, "the interrupted prefix must have speculated");
    let mut states =
        ckpt::load_latest(&dir2, ResumeMode::Strict, 1).unwrap().expect("ckpt");
    let state = states.remove(0);
    assert_eq!(state.cursor, k as u64, "quiescent cursor covers the drained prefix");
    let srv2 = Server::resume(
        cfg.clone(),
        b.classes,
        expert_for(&b, 67),
        spec_cfg,
        "artifacts",
        state,
    )
    .unwrap();
    let (report2, responses2) = run_range(srv2, &b, k, n);
    assert!(report2.resumed);
    assert_eq!(responses2.len(), n - k, "only the tail is re-served");
    assert_eq!(report2.served, n, "cumulative counters continue the first run");
    let bits = |r: &ServeReport| {
        r.final_betas.iter().map(|x| x.to_bits()).collect::<Vec<u64>>()
    };
    assert_eq!(
        bits(&report2),
        bits(&report),
        "a barrier taken mid-speculation must resume bit-identical"
    );
    assert_eq!(report2.train_batches, report.train_batches);
    assert_eq!(report2.calib_batches, report.calib_batches);
    assert_eq!(report2.llm_calls, report.llm_calls);
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&dir2);
}
