//! Reproduction-record invariants (`report` module, DESIGN.md §10):
//!
//! * the report JSON round-trips through the codec bit-for-bit;
//! * the markdown emitter is deterministic across runs at a fixed
//!   (scale, seed) — the property CI's `reproduce-quick` byte-diff
//!   gate relies on;
//! * tolerance-band pass/fail logic behaves on synthetic deltas of
//!   every band kind.

use ocl::codec;
use ocl::config::{BenchmarkId, ExpertId};
use ocl::report::{
    reproduce, Band, BandKind, Measurement, Report, ReproduceOpts, Row, SCHEMA_VERSION, Section,
    Status,
};

fn tiny_opts() -> ReproduceOpts {
    // One non-IMDB benchmark keeps the pipeline to its cheapest shape
    // (Table 1 + costmodel sections) at the minimum stream size.
    ReproduceOpts {
        profile: "test".to_string(),
        scale: 0.02,
        seeds: vec![1],
        expert: ExpertId::Gpt35,
        benches: vec![BenchmarkId::Fever],
    }
}

fn synthetic_row(paper: Option<f64>, band: Option<Band>, mean: f64) -> Row {
    Row {
        label: "synthetic".to_string(),
        unit: "%".to_string(),
        paper,
        band,
        measured: Measurement { mean, sd: 0.01, n: 3 },
    }
}

#[test]
fn tolerance_bands_on_synthetic_deltas() {
    let two = Some(Band { kind: BandKind::TwoSided, tol: 0.05 });
    // Inside, at the edge, and outside — both directions.
    assert_eq!(synthetic_row(Some(0.9), two, 0.9).status(), Status::Pass);
    assert_eq!(synthetic_row(Some(0.9), two, 0.95).status(), Status::Pass);
    assert_eq!(synthetic_row(Some(0.9), two, 0.851).status(), Status::Fail);
    assert_eq!(synthetic_row(Some(0.9), two, 0.96).status(), Status::Fail);
    // Upper bound: arbitrarily below passes, above the slack fails.
    let up = Some(Band { kind: BandKind::UpperBound, tol: 0.02 });
    assert_eq!(synthetic_row(Some(0.0), up, -3.0).status(), Status::Pass);
    assert_eq!(synthetic_row(Some(0.0), up, 0.021).status(), Status::Fail);
    // Lower bound: arbitrarily above passes, below the slack fails.
    let low = Some(Band { kind: BandKind::LowerBound, tol: 0.02 });
    assert_eq!(synthetic_row(Some(0.5), low, 0.99).status(), Status::Pass);
    assert_eq!(synthetic_row(Some(0.5), low, 0.47).status(), Status::Fail);
    // No reference → info, and info rows never fail a report.
    assert_eq!(synthetic_row(None, None, 0.1).status(), Status::Info);
    let rep = Report {
        profile: "t".to_string(),
        scale: 1.0,
        seeds: vec![1],
        expert: ExpertId::Gpt35,
        sections: vec![Section {
            id: "s".to_string(),
            title: "S".to_string(),
            rows: vec![synthetic_row(None, None, 0.1), synthetic_row(Some(0.9), two, 0.9)],
        }],
    };
    assert!(rep.passed());
}

#[test]
fn report_json_round_trips_through_codec() {
    let rep = reproduce(&tiny_opts()).expect("tiny reproduce");
    assert!(rep.rows() >= 8, "fever table1 + costmodel rows expected");
    let json = rep.to_json();
    let text = json.to_string_pretty();
    let back = Report::from_json(&codec::parse(&text).expect("parse")).expect("from_json");
    assert_eq!(back, rep, "Report must survive encode → parse → decode");
    // Re-encoding is a fixed point (derived fields recompute identically).
    assert_eq!(back.to_json().to_string_pretty(), text);
    // Schema drift is rejected.
    let drifted = text.replacen(
        &format!("\"schema\": {SCHEMA_VERSION}"),
        &format!("\"schema\": {}", SCHEMA_VERSION + 1),
        1,
    );
    assert!(Report::from_json(&codec::parse(&drifted).unwrap()).is_err());
    // A hand-edited verdict is rejected: stored status/delta must agree
    // with what the loaded values recompute.
    let tampered = text.replacen("\"status\": \"pass\"", "\"status\": \"FAIL\"", 1);
    assert_ne!(tampered, text, "record should contain at least one passing row");
    assert!(Report::from_json(&codec::parse(&tampered).unwrap()).is_err());
}

#[test]
fn markdown_and_json_deterministic_at_fixed_seed() {
    let a = reproduce(&tiny_opts()).expect("run a");
    let b = reproduce(&tiny_opts()).expect("run b");
    assert_eq!(a.to_markdown(), b.to_markdown(), "markdown must be byte-identical");
    assert_eq!(
        a.to_json().to_string_pretty(),
        b.to_json().to_string_pretty(),
        "json must be byte-identical"
    );
    let md = a.to_markdown();
    assert!(md.contains("| metric | paper | measured | Δ | band | status |"));
    assert!(md.contains("Table 1 — fever"));
    assert!(md.contains("App. B.1"));
    assert!(!md.contains("NaN"), "no NaN may ever reach the record");
}

#[test]
fn write_then_check_file_round_trips() {
    let rep = reproduce(&tiny_opts()).expect("reproduce");
    let dir = std::env::temp_dir().join(format!("ocl_report_test_{}", std::process::id()));
    let dir_s = dir.to_str().expect("utf8 tempdir").to_string();
    let (jp, mp) = rep.write(&dir_s).expect("write");
    assert!(jp.ends_with("reproduce_test.json") && mp.ends_with("reproduce_test.md"));
    let back = ocl::report::check_file(&jp).expect("check_file");
    assert_eq!(back, rep);
    let md = std::fs::read_to_string(&mp).expect("read md");
    assert_eq!(md, rep.to_markdown());
    std::fs::remove_dir_all(&dir).ok();
}
