//! Bench: time the Fig 9 + Table 2 distribution-shift grid at bench
//! scale — one case per §5.4 scenario, each executing the shared
//! registry's cells for that scenario (the exact workload `eval::shift`
//! renders). `cargo bench --bench bench_shift`

use ocl::bench_support::{black_box, Bench};
use ocl::config::ExpertId;
use ocl::eval::Harness;
use ocl::report::registry;

fn main() {
    let h = Harness::new(0.04, 5);
    let mut b = Bench::new("fig 9 / table 2 shifts (scaled)", 0, 1);
    for (name, order) in registry::shift_scenarios() {
        let specs = registry::shift_specs(ExpertId::Gpt35, name, order);
        b.case(&format!("imdb shift {name} gpt35"), || {
            for spec in &specs {
                let r = spec.execute(&h).expect("shift spec");
                black_box(r.accuracy);
            }
        });
    }
    b.print();
}
