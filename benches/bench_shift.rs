//! Bench: regenerate Fig 9 + Table 2 (distribution-shift robustness)
//! at bench scale. `cargo bench --bench bench_shift`

use ocl::bench_support::Bench;
use ocl::config::ExpertId;
use ocl::eval::{shift, Harness};

fn main() {
    let h = Harness::new(0.04, 5);
    let mut b = Bench::new("fig 9 / table 2 shifts (scaled)", 0, 1);
    b.case("imdb shifts gpt35", || {
        let s = shift(&h, ExpertId::Gpt35).expect("shift");
        println!("{s}");
    });
    b.print();
}
