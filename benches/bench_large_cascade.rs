//! Bench: Fig 11 (4-level cascade with BERT-large) + the deferral-rule
//! ablation DESIGN.md calls out. `cargo bench --bench bench_large_cascade`

use ocl::bench_support::Bench;
use ocl::cascade::{Cascade, DeferralRule};
use ocl::config::{BenchmarkId, CascadeConfig, ExpertId};
use ocl::data::Benchmark;
use ocl::eval::{curves, Harness};
use ocl::sim::{Expert, ExpertProfile};

fn main() {
    let h = Harness::new(0.04, 6);
    let mut b = Bench::new("fig 11 large cascade + ablations (scaled)", 0, 1);
    b.case("fig11 isear gpt35 (4-level)", || {
        let s = curves(&h, BenchmarkId::Isear, ExpertId::Gpt35, true).expect("fig11");
        println!("{s}");
    });

    // Deferral-rule ablation (calibrated vs max-prob vs entropy).
    let n = 1500usize;
    let bench = BenchmarkId::Imdb;
    let data = Benchmark::build_sized(bench, 8, n);
    let mean_len = data.samples.iter().map(|s| s.len as f64).sum::<f64>() / n as f64;
    for (tag, rule) in [
        ("deferral=calibrated", DeferralRule::Calibrated),
        ("deferral=maxprob", DeferralRule::MaxProb(0.8)),
        ("deferral=entropy", DeferralRule::Entropy(0.45)),
    ] {
        b.case(&format!("ablation {tag}"), || {
            let expert = Expert::new(
                ExpertProfile::for_pair(ExpertId::Gpt35, bench),
                data.strata_fractions(),
                mean_len,
                8,
            );
            let cfg = CascadeConfig::small(bench, ExpertId::Gpt35);
            let mut c = Cascade::new(cfg, 2, expert, None, n + 1).expect("cascade");
            c.set_threshold_scale(0.7);
            c.set_deferral_rule(rule);
            c.set_budget(Some((n / 5) as u64));
            let acc = c.run_stream(&data.stream());
            println!("{tag}: acc={:.2}% llm_calls={}", acc * 100.0, c.llm_calls());
        });
    }
    b.print();
}
