//! Bench: App. B.1 prefill-latency replay + C.1 cost equilibrium
//! (analytic — included so every table/figure has a regenerator).
//! `cargo bench --bench bench_costmodel`

use ocl::bench_support::Bench;
use ocl::eval::costmodel;

fn main() {
    let mut b = Bench::new("costmodel (B.1 + C.1)", 0, 3);
    b.case("render cost analyses", || {
        println!("{}", costmodel());
    });
    b.print();
}
