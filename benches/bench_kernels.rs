//! Bench: host-model kernel microbenchmarks — the committed perf
//! trajectory for the pure-rust inference path.
//!
//! Three groups:
//! 1. `matmul` sparse vs dense at the exact shapes `TfmArch::dims`
//!    produces (attention/FFN projections for both presets);
//! 2. per-model forward (HostTfm / HostLr / HostMlp) at batch 1/8/32,
//!    per-sample loop vs the batched `predict_batch_into` kernels;
//! 3. a ns/query + speedup-vs-per-sample table derived from (2).
//!
//! Emits the JSON baseline (`target/bench_kernels.json`, override with
//! `BENCH_KERNELS_JSON`); the committed copy at the repo root
//! (`BENCH_KERNELS.json`, refreshed by `make bench-commit`) is what CI
//! gates against via `--baseline`. With `BENCH_KERNELS_GATE=1` the run
//! additionally asserts the tentpole speedup: batched HostTfm at b=8
//! must be ≥2× the per-sample path per query.
//! `cargo bench --bench bench_kernels`

use ocl::bench_support::{self, black_box, Bench};
use ocl::codec::Json;
use ocl::hostmodel::tensor as t;
use ocl::hostmodel::{HostLr, HostMlp, HostTfm, TfmArch, TfmScratch};
use ocl::prng::Rng;

/// Random dense matrix in [-1, 1).
fn mat(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect()
}

fn mom(bench: &Bench, name: &str) -> f64 {
    bench
        .results()
        .iter()
        .find(|r| r.name == name)
        .map(|r| r.mom_ms())
        .unwrap_or(0.0)
}

fn bench_matmul(bench: &mut Bench, rng: &mut Rng) {
    // The shapes every transformer layer actually runs: [L,d]·[d,d]
    // (Q/K/V/O), [L,d]·[d,f] (FFN up), [L,f]·[f,d] (FFN down).
    for (tag, arch) in [("base", TfmArch::Base), ("large", TfmArch::Large)] {
        let (_v, l, d, _h, _lay, f) = arch.dims();
        for (m, k, n) in [(l, d, d), (l, d, f), (l, f, d)] {
            let a = mat(rng, m * k);
            let b = mat(rng, k * n);
            let mut c = vec![0.0f32; m * n];
            let reps = 8;
            let name_s = format!("matmul-sparse-{tag}-{m}x{k}x{n}");
            bench.case_throughput(&name_s, reps as f64, || {
                for _ in 0..reps {
                    t::matmul(&a, &b, &mut c, m, k, n);
                }
                black_box(&c);
            });
            let name_d = format!("matmul-dense-{tag}-{m}x{k}x{n}");
            bench.case_throughput(&name_d, reps as f64, || {
                for _ in 0..reps {
                    t::matmul_dense(&a, &b, &mut c, m, k, n);
                }
                black_box(&c);
            });
        }
    }
}

fn main() {
    let mut rng = Rng::new(0xBE9C);
    let mut bench = Bench::new("host kernels", 2, 7);

    bench_matmul(&mut bench, &mut rng);

    // --- HostTfm forward: per-sample reference vs fused batch -------
    let classes = 4;
    let tfm = HostTfm::new(TfmArch::Base, classes, 7);
    let (vocab, l, _d, _h, _lay, _f) = TfmArch::Base.dims();
    let max_b = 32;
    let ids: Vec<Vec<i32>> = (0..max_b)
        .map(|_| (0..l).map(|_| rng.below(vocab) as i32).collect())
        .collect();
    let masks: Vec<Vec<f32>> = (0..max_b)
        .map(|_| {
            let live = l / 2 + rng.below(l / 2);
            (0..l).map(|i| if i < live { 1.0 } else { 0.0 }).collect()
        })
        .collect();
    let idr: Vec<&[i32]> = ids.iter().map(|v| v.as_slice()).collect();
    let mr: Vec<&[f32]> = masks.iter().map(|v| v.as_slice()).collect();
    let mut scratch = TfmScratch::new();
    let mut out = vec![0.0f32; max_b * classes];
    for b in [1usize, 8, 32] {
        bench.case_throughput(&format!("tfm-base-persample-b{b}"), b as f64, || {
            for i in 0..b {
                black_box(tfm.predict(&ids[i], &masks[i]));
            }
        });
        bench.case_throughput(&format!("tfm-base-batched-b{b}"), b as f64, || {
            tfm.predict_batch_into(
                &idr[..b],
                &mr[..b],
                &mut scratch,
                &mut out[..b * classes],
            );
            black_box(&out);
        });
    }

    // --- HostLr forward (hashed bag-of-words style sparse rows) -----
    let dim = 4096;
    let lr = {
        let mut m = HostLr::new(dim, classes);
        let xs: Vec<Vec<f32>> = (0..8)
            .map(|_| {
                let mut x = vec![0.0f32; dim];
                for _ in 0..64 {
                    x[rng.below(dim)] = rng.f32();
                }
                x
            })
            .collect();
        let ys: Vec<usize> = (0..8).map(|_| rng.below(classes)).collect();
        let xr: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
        m.train_batch(&xr, &ys, 0.3);
        m
    };
    let lr_xs: Vec<Vec<f32>> = (0..max_b)
        .map(|_| {
            let mut x = vec![0.0f32; dim];
            for _ in 0..64 {
                x[rng.below(dim)] = rng.f32();
            }
            x
        })
        .collect();
    let lr_xr: Vec<&[f32]> = lr_xs.iter().map(|v| v.as_slice()).collect();
    let mut lr_out = vec![0.0f32; max_b * classes];
    let lr_reps = 64;
    for b in [1usize, 8, 32] {
        bench.case_throughput(
            &format!("lr-persample-b{b}"),
            (lr_reps * b) as f64,
            || {
                for _ in 0..lr_reps {
                    for x in &lr_xr[..b] {
                        black_box(lr.predict(x));
                    }
                }
            },
        );
        bench.case_throughput(
            &format!("lr-batched-b{b}"),
            (lr_reps * b) as f64,
            || {
                for _ in 0..lr_reps {
                    lr.predict_batch_into(&lr_xr[..b], &mut lr_out[..b * classes]);
                }
                black_box(&lr_out);
            },
        );
    }

    // --- HostMlp calibrator score -----------------------------------
    let mlp = HostMlp::new(classes, 11);
    let mlp_ps: Vec<Vec<f32>> = (0..max_b)
        .map(|_| {
            let raw: Vec<f32> = (0..classes).map(|_| rng.f32() + 1e-3).collect();
            let s: f32 = raw.iter().sum();
            raw.iter().map(|v| v / s).collect()
        })
        .collect();
    let mlp_pr: Vec<&[f32]> = mlp_ps.iter().map(|v| v.as_slice()).collect();
    let mut feat = Vec::new();
    let mut mlp_out = vec![0.0f32; max_b];
    let mlp_reps = 256;
    for b in [1usize, 8, 32] {
        bench.case_throughput(
            &format!("mlp-persample-b{b}"),
            (mlp_reps * b) as f64,
            || {
                for _ in 0..mlp_reps {
                    for p in &mlp_pr[..b] {
                        black_box(mlp.predict(p));
                    }
                }
            },
        );
        bench.case_throughput(
            &format!("mlp-batched-b{b}"),
            (mlp_reps * b) as f64,
            || {
                for _ in 0..mlp_reps {
                    mlp.predict_batch_into(&mlp_pr[..b], &mut feat, &mut mlp_out[..b]);
                }
                black_box(&mlp_out);
            },
        );
    }

    bench.print();

    // --- ns/query + speedup table -----------------------------------
    // queries per iteration for each forward case (mirrors the
    // case_throughput registrations above).
    let qpi = |model: &str, b: usize| -> f64 {
        match model {
            "tfm-base" => b as f64,
            "lr" => (lr_reps * b) as f64,
            _ => (mlp_reps * b) as f64,
        }
    };
    println!("\n== kernels: ns/query (median-of-medians) ==");
    println!(
        "{:<12} {:>4} {:>16} {:>14} {:>12}",
        "model", "b", "per-sample ns", "batched ns", "speedup"
    );
    let mut speedup_rows: Vec<Json> = Vec::new();
    let mut tfm_b8_speedup = 0.0;
    for model in ["tfm-base", "lr", "mlp"] {
        for b in [1usize, 8, 32] {
            let per = mom(&bench, &format!("{model}-persample-b{b}"));
            let bat = mom(&bench, &format!("{model}-batched-b{b}"));
            let per_ns = per * 1e6 / qpi(model, b);
            let bat_ns = bat * 1e6 / qpi(model, b);
            let speedup = if bat_ns > 0.0 { per_ns / bat_ns } else { 0.0 };
            if model == "tfm-base" && b == 8 {
                tfm_b8_speedup = speedup;
            }
            println!(
                "{model:<12} {b:>4} {per_ns:>16.0} {bat_ns:>14.0} {speedup:>11.2}x"
            );
            speedup_rows.push(Json::obj(vec![
                ("model", Json::Str(model.to_string())),
                ("batch", Json::Num(b as f64)),
                ("per_sample_ns", Json::Num(per_ns)),
                ("batched_ns", Json::Num(bat_ns)),
                ("speedup", Json::Num(speedup)),
            ]));
        }
    }
    println!("kernels: tfm b8 batched speedup {tfm_b8_speedup:.2}x (gate >= 2x)");

    // Tentpole gate (CI sets BENCH_KERNELS_GATE=1; local runs on
    // loaded machines stay informational).
    if std::env::var("BENCH_KERNELS_GATE").as_deref() == Ok("1") {
        assert!(
            tfm_b8_speedup >= 2.0,
            "batched HostTfm b=8 speedup {tfm_b8_speedup:.2}x below the 2x gate"
        );
        println!("speedup gate passed");
    }

    // JSON baseline: harness timings + the derived speedup table (the
    // committed BENCH_KERNELS.json at the repo root is this file).
    let json = Json::obj(vec![
        ("harness", bench.to_json()),
        ("speedups", Json::Arr(speedup_rows)),
    ]);
    let path = std::env::var("BENCH_KERNELS_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../target/bench_kernels.json").to_string()
    });
    if let Some(dir) = std::path::Path::new(&path).parent() {
        std::fs::create_dir_all(dir).ok();
    }
    std::fs::write(&path, json.to_string_pretty()).expect("write json baseline");
    println!("json baseline written to {path}");

    // Regression gate (opt-in): compare this run's median-of-medians
    // against a stored baseline file (CI passes the committed one).
    if let Some((baseline, tol)) = bench_support::baseline_from_env() {
        bench_support::check_baseline_file(&bench, &baseline, tol)
            .expect("baseline regression gate");
        println!("baseline gate passed vs {baseline} (tolerance {tol}%)");
    }
}
