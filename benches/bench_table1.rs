//! Bench: time the Table 1 OCL cells (method comparison at matched
//! budgets) at bench scale via the shared experiment registry, then
//! print one full accuracy table for the record.
//!
//! `BENCH_TABLE1_BUDGET` selects the Table 1 budget column (0 = low,
//! 1 = mid, 2 = high; default mid) — the same knob style as
//! `bench_serve`'s `BENCH_SERVE_*` env vars.
//! `cargo bench --bench bench_table1`

use ocl::bench_support::Bench;
use ocl::config::{BenchmarkId, ExpertId};
use ocl::eval::Harness;
use ocl::report::registry::{self, Method};

fn main() {
    let idx: usize = match std::env::var("BENCH_TABLE1_BUDGET") {
        Ok(v) => v
            .trim()
            .parse()
            .unwrap_or_else(|_| panic!("BENCH_TABLE1_BUDGET: cannot parse '{v}'")),
        Err(_) => 1,
    };
    assert!(idx < 3, "BENCH_TABLE1_BUDGET must be 0 (low), 1 (mid), or 2 (high)");
    let h = Harness::new(0.04, 1);
    let mut b = Bench::new(&format!("table1 (scaled, budget column {idx})"), 0, 3);
    for bench in BenchmarkId::ALL {
        let spec = registry::table1_spec(bench, ExpertId::Gpt35, Method::Ocl, idx);
        let budget = spec.budget_calls(&h).unwrap_or(0);
        let n = h.stream_len(bench);
        b.case_throughput(&format!("{} (n={n}, budget={budget})", spec.name), n as f64, || {
            let r = spec.execute(&h).expect("run");
            ocl::bench_support::black_box(r.accuracy);
        });
    }
    // One accuracy table at the chosen budget column for the record.
    let h2 = Harness::new(0.04, 2);
    println!("{}", ocl::eval::table1(&h2, &[ExpertId::Gpt35]).expect("table1"));
    b.print();
}
