//! Bench: regenerate Table 1 rows (method comparison at matched
//! budgets) at bench scale, and time one full OCL stream per benchmark.
//! `cargo bench --bench bench_table1`

use ocl::bench_support::Bench;
use ocl::config::{BenchmarkId, ExpertId};
use ocl::data::StreamOrder;
use ocl::eval::{table1_budgets, Harness};

fn main() {
    let h = Harness::new(0.04, 1);
    let mut b = Bench::new("table1 (scaled)", 0, 3);
    for bench in BenchmarkId::ALL {
        let budget = h.scaled_budget(bench, table1_budgets(bench)[1]);
        let n = h.stream_len(bench);
        b.case_throughput(
            &format!("ocl {} (n={n}, budget={budget})", bench.name()),
            n as f64,
            || {
                let (r, _) = h
                    .run_ocl(bench, ExpertId::Gpt35, Some(budget), false, StreamOrder::Natural)
                    .expect("run");
                ocl::bench_support::black_box(r.accuracy);
            },
        );
    }
    // One accuracy table at the mid budget for the record.
    let h2 = Harness::new(0.04, 2);
    println!("{}", ocl::eval::table1(&h2, &[ExpertId::Gpt35]).expect("table1"));
    b.print();
}
