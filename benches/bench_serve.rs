//! Bench: serve-layer sustained throughput under open-loop load —
//! steady Poisson, ramp, and burst arrival processes against the
//! supervised router (`serve::Server`), plus a sharded/replicated
//! topology run when `BENCH_SERVE_SHARDS`/`BENCH_SERVE_REPLICAS` ask
//! for one. Prints the usual table and emits the JSON baseline
//! (`target/bench_serve.json`, override with `BENCH_SERVE_JSON`) that
//! CI uploads as the perf-trajectory artifact; `BENCH_SERVE_REQUESTS`
//! pins the scale (default 1200). Gate a run against a stored baseline
//! with `--baseline <file>` (or `BENCH_BASELINE`): >tolerance
//! median-of-medians regressions fail the process.
//! `cargo bench --bench bench_serve`

use std::cell::RefCell;
use std::net::TcpListener;
use std::sync::mpsc::channel;
use std::time::Duration;

use ocl::bench_support::{self, Bench};
use ocl::codec::Json;
use ocl::config::{BenchmarkId, CascadeConfig, ExpertId, ServeConfig, ShardConfig};
use ocl::data::Benchmark;
use ocl::serve::net;
use ocl::serve::shard::{ShardFront, ShardReport};
use ocl::serve::{load, ServeReport, Server};
use ocl::sim::{Expert, ExpertProfile};

fn setup(n: usize, seed: u64) -> (Benchmark, Expert, CascadeConfig) {
    let b = Benchmark::build_sized(BenchmarkId::Imdb, seed, n);
    let mean_len =
        b.samples.iter().map(|s| s.len as f64).sum::<f64>() / n.max(1) as f64;
    let expert = Expert::new(
        ExpertProfile::for_pair(ExpertId::Gpt35, BenchmarkId::Imdb),
        b.strata_fractions(),
        mean_len,
        seed,
    );
    let mut cfg = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
    cfg.seed = seed;
    (b, expert, cfg)
}

fn run_scenario(arrival: load::Arrival, n: usize, seed: u64) -> ServeReport {
    let (b, expert, cfg) = setup(n, seed);
    let mut server =
        Server::new(cfg, b.classes, expert, ServeConfig::default(), "artifacts")
            .expect("server");
    server.set_threshold_scale(0.7);

    let (req_tx, req_rx) = channel();
    let (resp_tx, resp_rx) = channel();
    let drain = std::thread::spawn(move || resp_rx.iter().count());
    let submit = load::drive(b.samples.clone(), arrival, seed ^ 0xA, req_tx);
    let report = server.serve(req_rx, resp_tx).expect("serve");
    assert_eq!(submit.join().expect("submit"), n);
    assert_eq!(drain.join().expect("drain"), n, "every request answered");
    assert_eq!(report.served + report.shed, n);
    report
}

fn run_sharded(
    arrival: load::Arrival,
    n: usize,
    seed: u64,
    shard: ShardConfig,
) -> ShardReport {
    let (b, expert, cfg) = setup(n, seed);
    let serve_cfg = ServeConfig::builder()
        .shards(shard.shards)
        .replicas_per_level(shard.replicas_per_level)
        .sync_interval(shard.sync_interval)
        .build()
        .expect("serve cfg");
    let mut front =
        ShardFront::new(cfg, b.classes, expert, serve_cfg, "artifacts").expect("front");
    front.set_threshold_scale(0.7);

    let (req_tx, req_rx) = channel();
    let (resp_tx, resp_rx) = channel();
    let drain = std::thread::spawn(move || resp_rx.iter().count());
    let submit = load::drive(b.samples.clone(), arrival, seed ^ 0xA, req_tx);
    let report = front.serve(req_rx, resp_tx).expect("serve sharded");
    assert_eq!(submit.join().expect("submit"), n);
    assert_eq!(drain.join().expect("drain"), n, "every request answered");
    assert_eq!(report.served() + report.shed(), n);
    report
}

/// Socket-backpressure probe: drive the wire front (`net::serve` on a
/// loopback listener, real `Client` + open-loop arrivals over TCP)
/// at a fixed offered rate against a deliberately small admission
/// budget, and report what the gate did — shed rate and the peak
/// population the budget ever held. The interesting output is the
/// *curve* across offered rates: shed_rate ≈ 0 and peak_pending well
/// under the cap while the server keeps up, then peak_pending pinning
/// at `max_pending` and shed_rate climbing once it can't.
fn run_tcp_backpressure(
    n: usize,
    seed: u64,
    offered_rps: f64,
    max_pending: usize,
) -> Json {
    let (b, expert, cfg) = setup(n, seed);
    let serve_cfg =
        ServeConfig::builder().max_pending(max_pending).build().expect("serve cfg");
    let mut front =
        ShardFront::new(cfg, b.classes, expert, serve_cfg, "artifacts").expect("front");
    front.set_threshold_scale(0.7);

    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().expect("listener addr").to_string();
    let server = std::thread::spawn(move || net::serve(front, listener));

    let client =
        net::Client::connect_retry(&addr, Duration::from_secs(10)).expect("connect");
    let submit = load::drive(
        b.samples.clone(),
        load::Arrival::Poisson { rate: offered_rps },
        seed ^ 0xB,
        client.request_sender(),
    );
    let (responses, _server_report_frame) = client.finish().expect("client finish");
    assert_eq!(submit.join().expect("submit"), n);
    let report = server.join().expect("server thread").expect("serve over tcp");
    assert_eq!(responses.len(), n, "every request answered or shed over the socket");
    assert_eq!(report.served() + report.shed(), n);
    assert!(report.peak_pending <= max_pending, "admission budget exceeded");

    let shed = report.shed();
    let lat = report.latency_ms();
    println!(
        "tcp-backpressure {offered_rps:>6.0}rps cap {max_pending}: served {} shed {} \
         (rate {:.3}) peak_pending {} p99 {:.2}ms",
        report.served(),
        shed,
        shed as f64 / n as f64,
        report.peak_pending,
        lat.pct(99.0)
    );
    Json::obj(vec![
        ("offered_rps", Json::Num(offered_rps)),
        ("requests", Json::Num(n as f64)),
        ("max_pending", Json::Num(max_pending as f64)),
        ("served", Json::Num(report.served() as f64)),
        ("shed", Json::Num(shed as f64)),
        ("shed_rate", Json::Num(shed as f64 / n as f64)),
        ("peak_pending", Json::Num(report.peak_pending as f64)),
        ("p50_ms", Json::Num(lat.pct(50.0))),
        ("p99_ms", Json::Num(lat.pct(99.0))),
    ])
}

/// Deferred-vs-direct latency split for one execution mode (the
/// tentpole acceptance rows): same open-loop stream, same cascade,
/// only the scheduling knobs differ. Uses the 4-level cascade —
/// speculation targets level k+2, so the 2-level topology would never
/// speculate — and reports p99 for requests answered at level 0
/// (direct) vs answered deeper or by the expert (deferred).
fn run_latency_split(mode: &str, serve_cfg: ServeConfig, n: usize, seed: u64) -> ServeReport {
    let (b, expert, _) = setup(n, seed);
    let mut cfg = CascadeConfig::large(BenchmarkId::Imdb, ExpertId::Gpt35);
    cfg.seed = seed;
    let mut server =
        Server::new(cfg, b.classes, expert, serve_cfg, "artifacts").expect("server");
    server.set_threshold_scale(0.7);

    let (req_tx, req_rx) = channel();
    let (resp_tx, resp_rx) = channel();
    let drain = std::thread::spawn(move || resp_rx.iter().count());
    let submit = load::drive(
        b.samples.clone(),
        load::Arrival::Poisson { rate: 1200.0 },
        seed ^ 0xA,
        req_tx,
    );
    let report = server.serve(req_rx, resp_tx).expect("serve");
    assert_eq!(submit.join().expect("submit"), n);
    assert_eq!(drain.join().expect("drain"), n, "every request answered");
    let d99 = report.latency_direct_ms.pct(99.0);
    let f99 = report.latency_deferred_ms.pct(99.0);
    println!(
        "latency-split {mode}: p99 direct {:.2}ms deferred {:.2}ms (ratio {:.2}) \
         spec hits {} wasted {} queue_depth {:?}",
        d99,
        f99,
        if d99 > 0.0 { f99 / d99 } else { 0.0 },
        report.spec_hits,
        report.spec_wasted,
        report.queue_depth
    );
    report
}

fn split_row(mode: &str, n: usize, r: &ServeReport) -> Json {
    Json::obj(vec![
        ("name", Json::Str(format!("latency-split-{mode}"))),
        ("requests", Json::Num(n as f64)),
        ("p99_direct_ms", Json::Num(r.latency_direct_ms.pct(99.0))),
        ("p99_deferred_ms", Json::Num(r.latency_deferred_ms.pct(99.0))),
        ("p50_direct_ms", Json::Num(r.latency_direct_ms.pct(50.0))),
        ("p50_deferred_ms", Json::Num(r.latency_deferred_ms.pct(50.0))),
        ("spec_hits", Json::Num(r.spec_hits as f64)),
        ("spec_wasted", Json::Num(r.spec_wasted as f64)),
    ])
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let n = env_usize("BENCH_SERVE_REQUESTS", 1200);
    let shards = env_usize("BENCH_SERVE_SHARDS", 1);
    let replicas = env_usize("BENCH_SERVE_REPLICAS", 1);
    let sync = env_usize("BENCH_SERVE_SYNC", 16);
    let scenarios: [(&str, load::Arrival); 3] = [
        ("poisson-steady-1200rps", load::Arrival::Poisson { rate: 1200.0 }),
        ("ramp-300-to-3000rps", load::Arrival::Ramp { start: 300.0, end: 3000.0 }),
        (
            "burst-300-4000rps",
            load::Arrival::Burst {
                base: 300.0,
                peak: 4000.0,
                period: Duration::from_millis(50),
                duty: 0.3,
            },
        ),
    ];

    // Topology selects the workload: the default 1×1 run measures the
    // three single-router scenarios; a sharded run (CI's second pass)
    // measures ONLY the sharded steady-state scenario, so the two CI
    // invocations never duplicate work.
    let single_router = shards == 1 && replicas == 1;
    let mut bench = Bench::new("serve load (open loop)", 0, 1);
    let reports: RefCell<Vec<ServeReport>> = RefCell::new(Vec::new());
    if single_router {
        for (i, (name, arrival)) in scenarios.iter().enumerate() {
            bench.case_throughput(name, n as f64, || {
                reports.borrow_mut().push(run_scenario(*arrival, n, 51 + i as u64));
            });
        }
    }
    // Deferred-vs-direct latency split across execution modes
    // (sequential round-trips vs stage-queue pipelining vs pipelining
    // with speculative dispatch) — the tentpole acceptance rows.
    let split_modes: [(&str, ServeConfig); 3] = [
        ("sequential", ServeConfig::default()),
        (
            "pipelined",
            ServeConfig::builder().pipeline(true).build().expect("serve cfg"),
        ),
        (
            "pipelined-spec",
            ServeConfig::builder()
                .pipeline(true)
                .spec_threshold(0.3) // aggressive: most deferrals speculate
                .build()
                .expect("serve cfg"),
        ),
    ];
    let split_reports: RefCell<Vec<ServeReport>> = RefCell::new(Vec::new());
    if single_router {
        for (i, (mode, split_cfg)) in split_modes.iter().enumerate() {
            let name = format!("latency-split-{mode}");
            bench.case_throughput(&name, n as f64, || {
                split_reports
                    .borrow_mut()
                    .push(run_latency_split(mode, *split_cfg, n, 81 + i as u64));
            });
        }
    }
    // sync_interval only activates when shards > 1 (ShardFront wires it).
    let shard_cfg = ShardConfig { shards, replicas_per_level: replicas, sync_interval: sync };
    let sharded: RefCell<Option<ShardReport>> = RefCell::new(None);
    if !single_router {
        let name = format!("poisson-steady-1200rps-s{shards}r{replicas}");
        bench.case_throughput(&name, n as f64, || {
            *sharded.borrow_mut() = Some(run_sharded(
                load::Arrival::Poisson { rate: 1200.0 },
                n,
                61,
                shard_cfg,
            ));
        });
    }
    bench.print();

    // Socket-backpressure curve (single-router CI pass only, so the
    // sharded invocation never duplicates it): offered load sweeps
    // from under to well over what the small admission budget absorbs.
    let mut tcp_rows: Vec<Json> = Vec::new();
    if single_router {
        let n_bp = env_usize("BENCH_SERVE_BP_REQUESTS", (n / 3).clamp(150, 400));
        let cap = env_usize("BENCH_SERVE_BP_CAP", 64);
        for (i, rps) in [600.0, 2_400.0, 6_000.0].into_iter().enumerate() {
            tcp_rows.push(run_tcp_backpressure(n_bp, 71 + i as u64, rps, cap));
        }
    }

    let reports = reports.into_inner();
    for ((name, _), r) in scenarios.iter().zip(&reports) {
        println!(
            "{name}: served {} shed {} restarts {:?} p50 {:.2}ms p99 {:.2}ms max {:.2}ms",
            r.served,
            r.shed,
            r.restarts,
            r.latency_ms.pct(50.0),
            r.latency_ms.pct(99.0),
            r.latency_ms.max()
        );
    }
    let sharded = sharded.into_inner();
    if let Some(r) = &sharded {
        let lat = r.latency_ms();
        println!(
            "sharded s{shards}r{replicas}: served {} shed {} p50 {:.2}ms p99 {:.2}ms \
             max snapshot lag {} chunks",
            r.served(),
            r.shed(),
            lat.pct(50.0),
            lat.pct(99.0),
            r.max_snapshot_lag()
        );
    }
    // SLO gate: intentionally generous (shared CI runners) — the point
    // is catching order-of-magnitude regressions, not µs drift.
    let slo = load::Slo { p50_ms: 2_000.0, p99_ms: 15_000.0 };
    if let Some(r) = reports.first() {
        slo.check(&r.latency_ms).expect("steady-state SLO");
    }
    if let Some(r) = &sharded {
        slo.check_sharded(r).expect("sharded steady-state SLO");
    }
    // Tentpole acceptance gate: with pipelining + speculation on, the
    // deferred path must approach the direct one — within 2× at p99,
    // with the same absolute-floor generosity the other gates give
    // shared CI runners (a sub-ms direct p99 must not turn scheduler
    // noise into a failure).
    let split_reports = split_reports.into_inner();
    if let Some(r) = split_reports.last() {
        assert!(
            r.spec_hits + r.spec_wasted > 0,
            "the speculative mode must actually speculate"
        );
        let d99 = r.latency_direct_ms.pct(99.0);
        let f99 = r.latency_deferred_ms.pct(99.0);
        assert!(
            f99 <= (2.0 * d99).max(2_000.0),
            "pipelined+speculative deferred p99 {f99:.2}ms exceeds 2x the \
             direct p99 {d99:.2}ms (floor 2s)"
        );
    }

    // JSON baseline: harness timings + per-scenario serve reports (the
    // sharded run reports its aggregate, staleness included).
    let mut serve_entries: Vec<Json> = scenarios
        .iter()
        .zip(&reports)
        .map(|((name, _), r)| {
            Json::obj(vec![
                ("name", Json::Str((*name).to_string())),
                ("requests", Json::Num(n as f64)),
                ("report", r.to_json()),
            ])
        })
        .collect();
    if let Some(r) = &sharded {
        serve_entries.push(Json::obj(vec![
            (
                "name",
                Json::Str(format!("poisson-steady-1200rps-s{shards}r{replicas}")),
            ),
            ("requests", Json::Num(n as f64)),
            ("topology", shard_cfg.to_json()),
            ("report", r.to_json()),
        ]));
    }
    let split_rows: Vec<Json> = split_modes
        .iter()
        .zip(&split_reports)
        .map(|((mode, _), r)| split_row(mode, n, r))
        .collect();
    let json = Json::obj(vec![
        ("harness", bench.to_json()),
        ("serve", Json::Arr(serve_entries)),
        ("latency_split", Json::Arr(split_rows)),
        ("tcp_backpressure", Json::Arr(tcp_rows)),
    ]);
    // Default next to the workspace target dir (cargo runs benches with
    // cwd = the package root, so a bare relative path would land in
    // rust/target/ instead).
    let path = std::env::var("BENCH_SERVE_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../target/bench_serve.json").to_string()
    });
    if let Some(dir) = std::path::Path::new(&path).parent() {
        std::fs::create_dir_all(dir).ok();
    }
    std::fs::write(&path, json.to_string_pretty()).expect("write json baseline");
    println!("json baseline written to {path}");

    // Regression gate (opt-in): compare this run's median-of-medians
    // against a stored baseline file.
    if let Some((baseline, tol)) = bench_support::baseline_from_env() {
        bench_support::check_baseline_file(&bench, &baseline, tol)
            .expect("baseline regression gate");
        println!("baseline gate passed vs {baseline} (tolerance {tol}%)");
    }
}
