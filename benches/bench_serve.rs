//! Bench: serve-layer sustained throughput under open-loop load —
//! steady Poisson, ramp, and burst arrival processes against the
//! supervised router (`serve::Server`). Prints the usual table and
//! emits the JSON baseline (`target/bench_serve.json`, override with
//! `BENCH_SERVE_JSON`) that CI uploads as the perf-trajectory
//! artifact; `BENCH_SERVE_REQUESTS` pins the scale (default 1200).
//! `cargo bench --bench bench_serve`

use std::cell::RefCell;
use std::sync::mpsc::channel;
use std::time::Duration;

use ocl::bench_support::Bench;
use ocl::codec::Json;
use ocl::config::{BenchmarkId, CascadeConfig, ExpertId, ServeConfig};
use ocl::data::Benchmark;
use ocl::serve::{load, Server, ServeReport};
use ocl::sim::{Expert, ExpertProfile};

fn run_scenario(arrival: load::Arrival, n: usize, seed: u64) -> ServeReport {
    let b = Benchmark::build_sized(BenchmarkId::Imdb, seed, n);
    let mean_len =
        b.samples.iter().map(|s| s.len as f64).sum::<f64>() / n.max(1) as f64;
    let expert = Expert::new(
        ExpertProfile::for_pair(ExpertId::Gpt35, BenchmarkId::Imdb),
        b.strata_fractions(),
        mean_len,
        seed,
    );
    let mut cfg = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
    cfg.seed = seed;
    let mut server =
        Server::new(cfg, b.classes, expert, ServeConfig::default(), "artifacts")
            .expect("server");
    server.set_threshold_scale(0.7);

    let (req_tx, req_rx) = channel();
    let (resp_tx, resp_rx) = channel();
    let drain = std::thread::spawn(move || resp_rx.iter().count());
    let submit = load::drive(b.samples.clone(), arrival, seed ^ 0xA, req_tx);
    let report = server.serve(req_rx, resp_tx).expect("serve");
    assert_eq!(submit.join().expect("submit"), n);
    assert_eq!(drain.join().expect("drain"), n, "every request answered");
    assert_eq!(report.served + report.shed, n);
    report
}

fn main() {
    let n: usize = std::env::var("BENCH_SERVE_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1200);
    let scenarios: [(&str, load::Arrival); 3] = [
        ("poisson-steady-1200rps", load::Arrival::Poisson { rate: 1200.0 }),
        ("ramp-300-to-3000rps", load::Arrival::Ramp { start: 300.0, end: 3000.0 }),
        (
            "burst-300-4000rps",
            load::Arrival::Burst {
                base: 300.0,
                peak: 4000.0,
                period: Duration::from_millis(50),
                duty: 0.3,
            },
        ),
    ];

    let mut bench = Bench::new("serve load (open loop)", 0, 1);
    let reports: RefCell<Vec<ServeReport>> = RefCell::new(Vec::new());
    for (i, (name, arrival)) in scenarios.iter().enumerate() {
        bench.case_throughput(name, n as f64, || {
            reports.borrow_mut().push(run_scenario(*arrival, n, 51 + i as u64));
        });
    }
    bench.print();

    let reports = reports.into_inner();
    for ((name, _), r) in scenarios.iter().zip(&reports) {
        println!(
            "{name}: served {} shed {} restarts {:?} p50 {:.2}ms p99 {:.2}ms max {:.2}ms",
            r.served,
            r.shed,
            r.restarts,
            r.latency_ms.pct(50.0),
            r.latency_ms.pct(99.0),
            r.latency_ms.max()
        );
    }
    // SLO gate: intentionally generous (shared CI runners) — the point
    // is catching order-of-magnitude regressions, not µs drift.
    load::Slo { p50_ms: 2_000.0, p99_ms: 15_000.0 }
        .check(&reports[0].latency_ms)
        .expect("steady-state SLO");

    // JSON baseline: harness timings + per-scenario serve reports.
    let json = Json::obj(vec![
        ("harness", bench.to_json()),
        (
            "serve",
            Json::Arr(
                scenarios
                    .iter()
                    .zip(&reports)
                    .map(|((name, _), r)| {
                        Json::obj(vec![
                            ("name", Json::Str((*name).to_string())),
                            ("requests", Json::Num(n as f64)),
                            ("report", r.to_json()),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    // Default next to the workspace target dir (cargo runs benches with
    // cwd = the package root, so a bare relative path would land in
    // rust/target/ instead).
    let path = std::env::var("BENCH_SERVE_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../target/bench_serve.json").to_string()
    });
    if let Some(dir) = std::path::Path::new(&path).parent() {
        std::fs::create_dir_all(dir).ok();
    }
    std::fs::write(&path, json.to_string_pretty()).expect("write json baseline");
    println!("json baseline written to {path}");
}
