//! Bench: the L3/runtime hot paths — PJRT executable dispatch (b1 vs
//! b8 batching), host-engine model inference, featurization, and the
//! end-to-end router throughput. The numbers recorded in
//! EXPERIMENTS.md §Perf come from this bench.
//! `cargo bench --bench bench_runtime`

use std::rc::Rc;

use ocl::bench_support::{black_box, Bench};
use ocl::config::{BenchmarkId, ModelKind};
use ocl::data::Benchmark;
use ocl::hostmodel::{HostLr, HostTfm, TfmArch};
use ocl::models::{LevelModel, Pipeline, PjrtLevel};
use ocl::runtime::{artifacts_available, PjrtEngine};

fn main() {
    let mut b = Bench::new("runtime hot paths", 2, 20);
    let data = Benchmark::build_sized(BenchmarkId::Imdb, 9, 64);
    let pipeline = Pipeline::default();
    let feats: Vec<_> = data.samples.iter().map(|s| pipeline.featurize(&s.text)).collect();

    // featurization
    let mut buf = pipeline.buffer();
    b.case_throughput("featurize (hash+index)", 64.0, || {
        for s in &data.samples {
            pipeline.featurize_into(&s.text, &mut buf);
        }
        black_box(&buf);
    });

    // host engine inference
    let lr = HostLr::new(4096, 2);
    b.case_throughput("host lr predict x64", 64.0, || {
        for f in &feats {
            black_box(lr.predict(&f.x));
        }
    });
    let tfm = HostTfm::new(TfmArch::Base, 2, 0);
    b.case_throughput("host tfm-base predict x8", 8.0, || {
        for f in feats.iter().take(8) {
            black_box(tfm.predict(&f.ids, &f.mask));
        }
    });

    // pjrt engine inference (artifact-gated)
    if artifacts_available("artifacts") {
        let engine = Rc::new(PjrtEngine::from_dir("artifacts").expect("engine"));
        let mut plr = PjrtLevel::new(engine.clone(), ModelKind::Lr, 2).expect("lr");
        b.case_throughput("pjrt lr predict b1 x64", 64.0, || {
            for f in &feats {
                black_box(plr.predict(f));
            }
        });
        let refs: Vec<&_> = feats.iter().collect();
        b.case_throughput("pjrt lr predict b8 x64", 64.0, || {
            black_box(plr.predict_batch(&refs));
        });
        let mut ptf = PjrtLevel::new(engine, ModelKind::TfmBase, 2).expect("tfm");
        b.case_throughput("pjrt tfm-base predict b1 x8", 8.0, || {
            for f in feats.iter().take(8) {
                black_box(ptf.predict(f));
            }
        });
        let refs8: Vec<&_> = feats.iter().take(8).collect();
        b.case_throughput("pjrt tfm-base predict b8 x8", 8.0, || {
            black_box(ptf.predict_batch(&refs8));
        });
    } else {
        eprintln!("artifacts/ missing — pjrt cases skipped (make artifacts)");
    }
    b.print();
}
