//! Bench: the L3/runtime hot paths — host-engine model inference,
//! featurization, and (with `--features pjrt` + built artifacts) PJRT
//! executable dispatch (b1 vs b8 batching). The numbers recorded in
//! DESIGN.md §10 (Perf) come from this bench.
//! `cargo bench --bench bench_runtime`

use ocl::bench_support::{black_box, Bench};
use ocl::config::BenchmarkId;
use ocl::data::Benchmark;
use ocl::hostmodel::{HostLr, HostTfm, TfmArch};
use ocl::models::{Featurized, Pipeline};

fn main() {
    let mut b = Bench::new("runtime hot paths", 2, 20);
    let data = Benchmark::build_sized(BenchmarkId::Imdb, 9, 64);
    let pipeline = Pipeline::default();
    let feats: Vec<_> = data.samples.iter().map(|s| pipeline.featurize(&s.text)).collect();

    // featurization
    let mut buf = pipeline.buffer();
    b.case_throughput("featurize (hash+index)", 64.0, || {
        for s in &data.samples {
            pipeline.featurize_into(&s.text, &mut buf);
        }
        black_box(&buf);
    });

    // host engine inference
    let lr = HostLr::new(4096, 2);
    b.case_throughput("host lr predict x64", 64.0, || {
        for f in &feats {
            black_box(lr.predict(&f.x));
        }
    });
    let tfm = HostTfm::new(TfmArch::Base, 2, 0);
    b.case_throughput("host tfm-base predict x8", 8.0, || {
        for f in feats.iter().take(8) {
            black_box(tfm.predict(&f.ids, &f.mask));
        }
    });

    pjrt_cases(&mut b, &feats);
    b.print();
}

/// PJRT engine inference cases (feature- and artifact-gated).
#[cfg(feature = "pjrt")]
fn pjrt_cases(b: &mut Bench, feats: &[Featurized]) {
    use ocl::config::ModelKind;
    use ocl::models::{LevelModel, PjrtLevel};
    use ocl::runtime::{artifacts_available, worker_engine, DEFAULT_ARTIFACTS_DIR};

    if !artifacts_available(DEFAULT_ARTIFACTS_DIR) {
        eprintln!("artifacts/ missing — pjrt cases skipped (make artifacts)");
        return;
    }
    let engine = worker_engine(DEFAULT_ARTIFACTS_DIR);
    let mut plr = PjrtLevel::new(engine.clone(), ModelKind::Lr, 2).expect("lr");
    b.case_throughput("pjrt lr predict b1 x64", 64.0, || {
        for f in feats {
            black_box(plr.predict(f));
        }
    });
    let refs: Vec<&_> = feats.iter().collect();
    b.case_throughput("pjrt lr predict b8 x64", 64.0, || {
        black_box(plr.predict_batch(&refs));
    });
    let mut ptf = PjrtLevel::new(engine, ModelKind::TfmBase, 2).expect("tfm");
    b.case_throughput("pjrt tfm-base predict b1 x8", 8.0, || {
        for f in feats.iter().take(8) {
            black_box(ptf.predict(f));
        }
    });
    let refs8: Vec<&_> = feats.iter().take(8).collect();
    b.case_throughput("pjrt tfm-base predict b8 x8", 8.0, || {
        black_box(ptf.predict_batch(&refs8));
    });
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_cases(_b: &mut Bench, _feats: &[Featurized]) {
    eprintln!("built without the `pjrt` feature — pjrt cases skipped");
}
