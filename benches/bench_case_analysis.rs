//! Bench: regenerate the Figs 5-8 case-analysis time series at bench
//! scale. `cargo bench --bench bench_case_analysis`

use ocl::bench_support::Bench;
use ocl::config::{BenchmarkId, ExpertId};
use ocl::eval::{case_analysis, Harness};

fn main() {
    let h = Harness::new(0.06, 4);
    let mut b = Bench::new("figs 5-8 case analysis (scaled)", 0, 1);
    for bench in BenchmarkId::ALL {
        b.case(&format!("case {}", bench.name()), || {
            let s = case_analysis(&h, bench, ExpertId::Gpt35).expect("case");
            println!("{s}");
        });
    }
    b.print();
}
