//! Bench: regenerate the Figs 3/4 cost-accuracy curves (and the Fig 10
//! PRF metric set via the HateSpeech row) at bench scale.
//! `cargo bench --bench bench_fig_curves`

use ocl::bench_support::Bench;
use ocl::config::{BenchmarkId, ExpertId};
use ocl::eval::{curves, Harness};

fn main() {
    let h = Harness::new(0.04, 3);
    let mut b = Bench::new("fig 3/4/10 curves (scaled)", 0, 1);
    for bench in [BenchmarkId::Imdb, BenchmarkId::HateSpeech] {
        for expert in [ExpertId::Gpt35, ExpertId::Llama70b] {
            b.case(&format!("curves {} {}", bench.name(), expert.name()), || {
                let s = curves(&h, bench, expert, false).expect("curves");
                println!("{s}");
            });
        }
    }
    b.print();
}
