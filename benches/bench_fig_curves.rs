//! Bench: time the Figs 3/4 cost–accuracy curve sweeps (and the Fig 10
//! PRF metric set via the HateSpeech row) at bench scale. Each case
//! executes the shared registry's full budget sweep for one
//! (benchmark, expert) pair — the exact workload `eval::curves`
//! renders. `cargo bench --bench bench_fig_curves`

use ocl::bench_support::{black_box, Bench};
use ocl::config::{BenchmarkId, ExpertId};
use ocl::eval::Harness;
use ocl::report::registry;

fn main() {
    let h = Harness::new(0.04, 3);
    let mut b = Bench::new("fig 3/4/10 curves (scaled)", 0, 1);
    for bench in [BenchmarkId::Imdb, BenchmarkId::HateSpeech] {
        for expert in [ExpertId::Gpt35, ExpertId::Llama70b] {
            let specs = registry::curve_specs(bench, expert, false);
            b.case(&format!("curves {} {}", bench.name(), expert.name()), || {
                for spec in &specs {
                    let r = spec.execute(&h).expect("curve spec");
                    black_box(r.accuracy);
                }
            });
        }
    }
    b.print();
}
