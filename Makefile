# Build/verify/reproduce drivers for the ocl workspace.
#
# The reproduction record (DESIGN.md §10) regenerates byte-identically
# at a pinned (scale, seeds): `make reproduce` refreshes the committed
# `reports/reproduce_full.{json,md}`, `make reproduce-quick` the CI
# smoke profile. Everything runs offline against the host engine.

CARGO ?= cargo
BIN   := target/release/ocl

.PHONY: all build test lint loom reproduce reproduce-quick reports-check docs bench-serve bench-kernels bench-commit bench-check clean

all: build

build:
	$(CARGO) build --release

# Tier-1 verify (ROADMAP.md).
test: build
	$(CARGO) test -q

# The pinned reproduction record: full profile (scale 0.1, seeds 1-3).
# Splice the regenerated tables into DESIGN.md §10 when they change.
reproduce: build
	$(BIN) reproduce --profile full --out reports

# CI smoke profile: tiny pinned scale (0.02), one seed. Byte-identical
# across runs; CI diffs the result against the committed reports/.
reproduce-quick: build
	$(BIN) reproduce --profile quick --out reports

# Record gate: the committed report files must parse at the supported
# schema version AND have every tolerance band passing (a reproduction
# bound is an SLO; --check exits nonzero on band failures).
reports-check: build
	$(BIN) reproduce --check --profile quick --out reports
	$(BIN) reproduce --check --profile full --out reports

# Rustdoc with warnings denied (the CI docs job).
docs:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps

# Concurrency-invariant source pass (DESIGN.md §11): sync funnel,
# serve-path unwrap discipline, replay determinism, bounded frames.
lint:
	$(CARGO) run --bin ocl_lint -- --json ocl-lint-report.json

# Exhaustive interleaving exploration of the serve protocol cores
# (bounded profile runs inside plain `make test` already).
loom:
	RUSTFLAGS="--cfg loom" $(CARGO) test --release --test test_loom

# Serve-layer throughput numbers quoted in DESIGN.md §10 (machine-
# dependent — not part of the byte-identical record).
bench-serve:
	$(CARGO) bench --bench bench_serve

# Host-model kernel microbenches (matmul sparse/dense, batched vs
# per-sample forward at b=1/8/32) with the tentpole ≥2× speedup gate.
bench-kernels:
	BENCH_KERNELS_GATE=1 $(CARGO) bench --bench bench_kernels

# Refresh the committed perf trajectory (DESIGN.md §12): rerun both
# bench binaries with their JSON baselines pointed at the repo root,
# then commit the updated BENCH_*.json alongside the PR.
# (absolute paths: cargo runs bench binaries with cwd = rust/)
bench-commit:
	BENCH_KERNELS_JSON=$(CURDIR)/BENCH_KERNELS.json $(CARGO) bench --bench bench_kernels
	BENCH_SERVE_JSON=$(CURDIR)/BENCH_SERVE.json $(CARGO) bench --bench bench_serve

# Gate the current tree against the committed baselines (what CI runs;
# tolerance is generous — the gate is for order-of-magnitude drift).
bench-check:
	BENCH_KERNELS_GATE=1 $(CARGO) bench --bench bench_kernels -- \
		--baseline $(CURDIR)/BENCH_KERNELS.json --baseline-tol 100
	$(CARGO) bench --bench bench_serve -- \
		--baseline $(CURDIR)/BENCH_SERVE.json --baseline-tol 100

clean:
	$(CARGO) clean
