"""L2 correctness: model graphs (shapes, parity, learning dynamics)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.models import lr, mlp, transformer

jax.config.update("jax_platform_name", "cpu")


def _lr_params(c):
    return [jnp.asarray(a) for _, a in lr.init_params(model.HASH_DIM, c)]


def _tfm_params(arch, c):
    return [jnp.asarray(a) for _, a in transformer.init_params(arch, c)]


def _mlp_params(c):
    return [jnp.asarray(a) for _, a in mlp.init_params(c)]


def _doc(rng, b):
    ids = jnp.asarray(rng.integers(0, model.VOCAB, (b, model.SEQ_LEN)), jnp.int32)
    lens = rng.integers(5, model.SEQ_LEN, b)
    mask = np.zeros((b, model.SEQ_LEN), np.float32)
    for i, n in enumerate(lens):
        mask[i, :n] = 1.0
    return ids, jnp.asarray(mask)


class TestLR:
    @pytest.mark.parametrize("c", [2, 7])
    def test_forward_shape_and_simplex(self, c):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(0, 1, (8, model.HASH_DIM)), jnp.float32)
        (probs,) = lr.forward(x, *_lr_params(c))
        assert probs.shape == (8, c)
        np.testing.assert_allclose(np.sum(probs, -1), np.ones(8), rtol=1e-5)

    def test_step_matches_ref_step(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(0, 1, (8, model.HASH_DIM)), jnp.float32)
        y = jnp.asarray(np.eye(2, dtype=np.float32)[rng.integers(0, 2, 8)])
        w, b = _lr_params(2)
        w = w + 0.01  # move off the zero init so grads are non-trivial
        got = lr.step(x, y, w, b, jnp.float32(0.1))
        want = lr.step_ref(x, y, w, b, jnp.float32(0.1))
        for g, wnt in zip(got, want):
            np.testing.assert_allclose(g, wnt, rtol=1e-4, atol=1e-6)

    def test_learns_linearly_separable_stream(self):
        """Online LR must drive accuracy high on separable data."""
        rng = np.random.default_rng(2)
        w, b = _lr_params(2)
        centers = rng.normal(0, 1, (2, model.HASH_DIM)).astype(np.float32)
        correct = total = 0
        for step_i in range(60):
            ys = rng.integers(0, 2, 8)
            x = jnp.asarray(
                centers[ys] + rng.normal(0, 0.3, (8, model.HASH_DIM)), jnp.float32
            )
            yoh = jnp.asarray(np.eye(2, dtype=np.float32)[ys])
            (probs,) = lr.forward(x, w, b)
            if step_i >= 40:
                correct += int(np.sum(np.argmax(probs, -1) == ys))
                total += 8
            w, b, _ = lr.step(x, yoh, w, b, jnp.float32(0.5))
        assert correct / total > 0.9


class TestTransformer:
    @pytest.mark.parametrize("arch", ["base", "large"])
    @pytest.mark.parametrize("c", [2, 7])
    def test_forward_shape(self, arch, c):
        rng = np.random.default_rng(3)
        ids, mask = _doc(rng, 2)
        fwd = transformer.make_forward(arch, c, use_pallas=False)
        (probs,) = jax.jit(fwd)(ids, mask, *_tfm_params(arch, c))
        assert probs.shape == (2, c)
        np.testing.assert_allclose(np.sum(probs, -1), np.ones(2), rtol=1e-5)

    def test_pallas_matches_ref_forward(self):
        rng = np.random.default_rng(4)
        ids, mask = _doc(rng, 2)
        params = _tfm_params("base", 2)
        (pp,) = jax.jit(transformer.make_forward("base", 2, True))(ids, mask, *params)
        (pr,) = jax.jit(transformer.make_forward("base", 2, False))(ids, mask, *params)
        np.testing.assert_allclose(pp, pr, rtol=1e-4, atol=1e-6)

    def test_padding_tokens_do_not_affect_output(self):
        """Changing token ids under the pad mask must not change probs."""
        rng = np.random.default_rng(5)
        ids, mask = _doc(rng, 1)
        params = _tfm_params("base", 2)
        fwd = jax.jit(transformer.make_forward("base", 2, False))
        (p1,) = fwd(ids, mask, *params)
        noise = jnp.asarray(
            rng.integers(0, model.VOCAB, ids.shape), jnp.int32
        )
        ids2 = jnp.where(mask.astype(bool), ids, noise)
        (p2,) = fwd(ids2, mask, *params)
        np.testing.assert_allclose(p1, p2, rtol=1e-5, atol=1e-6)

    def test_step_reduces_loss(self):
        rng = np.random.default_rng(6)
        ids, mask = _doc(rng, 8)
        params = _tfm_params("base", 2)
        y = jnp.asarray(np.eye(2, dtype=np.float32)[rng.integers(0, 2, 8)])
        stp = jax.jit(transformer.make_step("base", 2))
        out = stp(ids, mask, y, *params, jnp.float32(5e-3))
        first = float(out[-1])
        for _ in range(5):
            out = stp(ids, mask, y, *out[:-1], jnp.float32(5e-3))
        assert float(out[-1]) < first

    def test_param_spec_matches_init(self):
        for arch in ("base", "large"):
            spec = transformer.param_spec(arch, 7)
            init = transformer.init_params(arch, 7)
            assert [n for n, _ in spec] == [n for n, _ in init]
            for (_, shp), (_, arr) in zip(spec, init):
                assert tuple(shp) == arr.shape


class TestMLP:
    @pytest.mark.parametrize("c", [2, 7])
    def test_forward_range(self, c):
        rng = np.random.default_rng(7)
        p = rng.dirichlet(np.ones(c), 8).astype(np.float32)
        (s,) = mlp.forward(jnp.asarray(p), *_mlp_params(c))
        assert s.shape == (8,)
        assert np.all((np.asarray(s) > 0) & (np.asarray(s) < 1))

    def test_step_learns_error_signal(self):
        """The calibrator must learn 'low max-prob => defer'."""
        rng = np.random.default_rng(8)
        params = _mlp_params(2)
        for _ in range(300):
            conf = rng.random(8).astype(np.float32) * 0.5 + 0.5
            p = np.stack([conf, 1 - conf], -1)
            z = (conf < 0.75).astype(np.float32)  # uncertain => wrong
            out = mlp.step(jnp.asarray(p), jnp.asarray(z), *params, jnp.float32(0.05))
            params = list(out[:-1])
        (s_sure,) = mlp.forward(jnp.asarray([[0.99, 0.01]], np.float32), *params)
        (s_unsure,) = mlp.forward(jnp.asarray([[0.55, 0.45]], np.float32), *params)
        assert float(s_unsure[0]) > float(s_sure[0])


class TestRegistry:
    def test_entry_count_and_naming(self):
        reg = model.entries()
        # per class count: lr(2 fwd + 1 step) + 2 arch * 3 + mlp(3) = 12
        assert len(reg) == 12 * len(model.CLASS_COUNTS)
        for name, ent in reg.items():
            assert ent["params_at"] >= 1
            assert ent["group"] in model.param_groups()

    def test_param_groups_cover_all_entries(self):
        groups = model.param_groups()
        for name, ent in model.entries().items():
            n_params = len(groups[ent["group"]])
            n_args = len(ent["args"])
            is_step = "_step_" in name
            assert ent["params_at"] + n_params + (1 if is_step else 0) == n_args
