"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/seeds; fixed adversarial cases cover softmax
overflow, fully-padded masks, and non-default block sizes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import flash_attention, fused_head, lr_grad_step, ref

jax.config.update("jax_platform_name", "cpu")

TOL = dict(rtol=1e-5, atol=1e-6)


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(0, 1, shape).astype(np.float32))


# --------------------------------------------------------------------------
# fused_head
# --------------------------------------------------------------------------
class TestFusedHead:
    @settings(max_examples=25, deadline=None)
    @given(
        b=st.sampled_from([1, 2, 4, 8, 16]),
        d=st.sampled_from([4, 32, 64, 4096]),
        c=st.integers(2, 7),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, b, d, c, seed):
        rng = np.random.default_rng(seed)
        x, w, bb = _rand(rng, b, d), _rand(rng, d, c), _rand(rng, c)
        got = fused_head(x, w, bb)
        want = ref.fused_head_ref(x, w, bb)
        np.testing.assert_allclose(got, want, **TOL)

    def test_rows_sum_to_one(self):
        rng = np.random.default_rng(0)
        out = fused_head(_rand(rng, 8, 64), _rand(rng, 64, 7), _rand(rng, 7))
        np.testing.assert_allclose(np.sum(out, -1), np.ones(8), rtol=1e-5)

    def test_large_logits_no_overflow(self):
        """Max-subtraction must keep exp() finite for huge logits."""
        x = jnp.full((8, 16), 100.0)
        w = jnp.full((16, 3), 10.0)
        b = jnp.asarray([0.0, 5.0, -5.0])
        out = np.asarray(fused_head(x, w, b))
        assert np.all(np.isfinite(out))
        np.testing.assert_allclose(out, ref.fused_head_ref(x, w, b), **TOL)

    def test_batch_one_block(self):
        rng = np.random.default_rng(3)
        x, w, b = _rand(rng, 1, 4096), _rand(rng, 4096, 2), _rand(rng, 2)
        np.testing.assert_allclose(
            fused_head(x, w, b), ref.fused_head_ref(x, w, b), **TOL
        )

    def test_indivisible_batch_raises(self):
        rng = np.random.default_rng(4)
        with pytest.raises(ValueError):
            fused_head(
                _rand(rng, 12, 8), _rand(rng, 8, 2), _rand(rng, 2), block_b=8
            )


# --------------------------------------------------------------------------
# flash_attention
# --------------------------------------------------------------------------
class TestFlashAttention:
    @settings(max_examples=20, deadline=None)
    @given(
        h=st.sampled_from([1, 2, 4, 6]),
        l=st.sampled_from([16, 32, 64]),
        dh=st.sampled_from([8, 16]),
        pad=st.integers(0, 10),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, h, l, dh, pad, seed):
        rng = np.random.default_rng(seed)
        q, k, v = (_rand(rng, h, l, dh) for _ in range(3))
        mask = np.ones(l, np.float32)
        if pad:
            mask[l - min(pad, l - 1):] = 0.0
        mask = jnp.asarray(mask)
        got = flash_attention(q, k, v, mask)
        want = ref.attention_ref(q, k, v, mask)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("block_k", [8, 16, 32, 64])
    def test_block_size_invariance(self, block_k):
        """The online-softmax result must not depend on the K tiling."""
        rng = np.random.default_rng(7)
        q, k, v = (_rand(rng, 4, 64, 16) for _ in range(3))
        mask = jnp.asarray(np.ones(64, np.float32))
        got = flash_attention(q, k, v, mask, block_k=block_k)
        want = ref.attention_ref(q, k, v, mask)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_single_token_mask(self):
        """All attention mass collapses onto the one unmasked key."""
        rng = np.random.default_rng(9)
        q, k, v = (_rand(rng, 2, 16, 8) for _ in range(3))
        mask = np.zeros(16, np.float32)
        mask[3] = 1.0
        out = flash_attention(q, k, v, jnp.asarray(mask))
        want = jnp.broadcast_to(v[:, 3:4, :], out.shape)
        np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)

    def test_extreme_scores_stable(self):
        q = jnp.full((1, 16, 8), 30.0)
        k = jnp.full((1, 16, 8), 30.0)
        v = jnp.asarray(np.random.default_rng(1).normal(0, 1, (1, 16, 8)), jnp.float32)
        mask = jnp.ones(16)
        out = np.asarray(flash_attention(q, k, v, mask))
        assert np.all(np.isfinite(out))


# --------------------------------------------------------------------------
# lr_grad_step
# --------------------------------------------------------------------------
class TestLrGradStep:
    @settings(max_examples=25, deadline=None)
    @given(
        b=st.sampled_from([1, 4, 8]),
        d=st.sampled_from([64, 512, 4096]),
        c=st.integers(2, 7),
        lr=st.floats(1e-4, 1.0),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, b, d, c, lr, seed):
        rng = np.random.default_rng(seed)
        x, g, w = _rand(rng, b, d), _rand(rng, b, c), _rand(rng, d, c)
        got = lr_grad_step(x, g, w, jnp.float32(lr))
        want = ref.lr_grad_step_ref(x, g, w, jnp.float32(lr))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_zero_gradient_is_identity(self):
        rng = np.random.default_rng(11)
        x, w = _rand(rng, 8, 512), _rand(rng, 512, 3)
        g = jnp.zeros((8, 3))
        np.testing.assert_allclose(lr_grad_step(x, g, w, jnp.float32(0.5)), w)

    def test_zero_lr_is_identity(self):
        rng = np.random.default_rng(12)
        x, g, w = _rand(rng, 8, 512), _rand(rng, 8, 3), _rand(rng, 512, 3)
        np.testing.assert_allclose(lr_grad_step(x, g, w, jnp.float32(0.0)), w)

    def test_update_direction_reduces_loss(self):
        """A real OGD step through the kernel must reduce cross-entropy."""
        rng = np.random.default_rng(13)
        x = _rand(rng, 8, 512)
        y = jnp.asarray(np.eye(2, dtype=np.float32)[rng.integers(0, 2, 8)])
        w = _rand(rng, 512, 2) * 0.01
        b = jnp.zeros(2)
        probs = ref.fused_head_ref(x, w, b)
        loss0 = float(ref.cross_entropy_ref(probs, y))
        for _ in range(5):
            probs = ref.fused_head_ref(x, w, b)
            w = lr_grad_step(x, probs - y, w, jnp.float32(0.05))
        probs = ref.fused_head_ref(x, w, b)
        assert float(ref.cross_entropy_ref(probs, y)) < loss0
