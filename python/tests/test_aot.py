"""AOT lowering: HLO text well-formed, manifest/blob consistency."""

import json
import os

import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def small_artifacts(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("art"))
    aot.main(["--out", out, "--only", "lr_fwd_c2,lr_step_c2,mlp_fwd_c2_b1"])
    return out


def test_hlo_text_is_parseable_hlo(small_artifacts):
    for fname in os.listdir(small_artifacts):
        if fname.endswith(".hlo.txt"):
            text = open(os.path.join(small_artifacts, fname)).read()
            assert text.startswith("HloModule"), fname
            assert "ENTRY" in text, fname


def test_manifest_shape_consistency(small_artifacts):
    man = json.load(open(os.path.join(small_artifacts, "manifest.json")))
    assert man["version"] == 1
    assert man["dims"]["hash_dim"] == model.HASH_DIM
    reg = model.entries()
    for name, ent in man["entries"].items():
        want = reg[name]
        assert len(ent["args"]) == len(want["args"])
        for got, spec in zip(ent["args"], want["args"]):
            assert got["shape"] == list(spec.shape)
        assert os.path.exists(os.path.join(small_artifacts, ent["hlo"]))


def test_init_blob_sizes_match_manifest(small_artifacts):
    man = json.load(open(os.path.join(small_artifacts, "manifest.json")))
    for gname, g in man["params"].items():
        want = sum(
            int(np.prod(t["shape"])) for t in g["tensors"]
        ) * 4  # f32
        got = os.path.getsize(os.path.join(small_artifacts, g["file"]))
        assert got == want, gname


def test_init_blob_roundtrip_values(small_artifacts):
    """Blob bytes must equal the in-memory init arrays, in order."""
    man = json.load(open(os.path.join(small_artifacts, "manifest.json")))
    groups = model.param_groups()
    g = man["params"]["tfm_base_c2"]
    blob = np.fromfile(os.path.join(small_artifacts, g["file"]), np.float32)
    off = 0
    for (name, arr), t in zip(groups["tfm_base_c2"], g["tensors"]):
        assert t["name"] == name
        n = arr.size
        np.testing.assert_array_equal(blob[off:off + n], arr.ravel())
        off += n
    assert off == blob.size


def test_entry_hlo_deterministic():
    """Lowering the same entry twice must produce identical text."""
    reg = model.entries()
    ent = reg["mlp_fwd_c2_b1"]
    assert aot.lower_entry("mlp_fwd_c2_b1", ent) == aot.lower_entry(
        "mlp_fwd_c2_b1", ent
    )
