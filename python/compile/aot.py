"""AOT lowering: every L2 entry point -> HLO text + manifest + init blobs.

Runs once at build time (``make artifacts``); the rust runtime loads
the results through the `xla` crate's text parser. HLO **text** — not
``.serialize()`` — is the interchange format: jax >= 0.5 emits protos
with 64-bit instruction ids that xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs under ``artifacts/``:

    <entry>.hlo.txt        one per entry point
    init/<group>.bin       f32 little-endian tensors, manifest order
    manifest.json          entries, arg shapes/dtypes, param groups, dims
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_tag(dt) -> str:
    return {"float32": "f32", "int32": "s32"}[np.dtype(dt).name]


def lower_entry(name, ent):
    lowered = jax.jit(ent["fn"]).lower(*ent["args"])
    return to_hlo_text(lowered)


def write_params(groups, out_dir):
    """Write each group as one concatenated f32-LE blob; return meta."""
    os.makedirs(os.path.join(out_dir, "init"), exist_ok=True)
    meta = {}
    for gname, pairs in sorted(groups.items()):
        path = os.path.join(out_dir, "init", f"{gname}.bin")
        with open(path, "wb") as f:
            for _, arr in pairs:
                f.write(np.ascontiguousarray(arr, np.float32).tobytes())
        meta[gname] = {
            "file": f"init/{gname}.bin",
            "tensors": [
                {"name": n, "shape": list(a.shape)} for n, a in pairs
            ],
        }
    return meta


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts dir")
    ap.add_argument(
        "--only", default=None,
        help="comma-separated entry-name substrings to lower (debugging)",
    )
    args = ap.parse_args(argv)
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)

    groups = model.param_groups()
    params_meta = write_params(groups, out_dir)

    reg = model.entries()
    wanted = args.only.split(",") if args.only else None
    manifest_entries = {}
    for name, ent in sorted(reg.items()):
        if wanted and not any(w in name for w in wanted):
            continue
        hlo = lower_entry(name, ent)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(hlo)
        manifest_entries[name] = {
            "hlo": fname,
            "sha256": hashlib.sha256(hlo.encode()).hexdigest()[:16],
            "args": [
                {"shape": list(a.shape), "dtype": _dtype_tag(a.dtype)}
                for a in ent["args"]
            ],
            "params_at": ent["params_at"],
            "group": ent["group"],
        }
        print(f"lowered {name}: {len(hlo)} chars", file=sys.stderr)

    manifest = {
        "version": 1,
        "dims": model.dims(),
        "params": params_meta,
        "entries": manifest_entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(
        f"wrote {len(manifest_entries)} entries + {len(params_meta)} "
        f"param groups to {out_dir}",
        file=sys.stderr,
    )


if __name__ == "__main__":
    main()
