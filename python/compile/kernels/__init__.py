"""L1 Pallas kernels (build-time only; lowered into the L2 HLO).

Every kernel here runs with ``interpret=True`` — the CPU PJRT plugin
that executes the AOT artifacts cannot run Mosaic custom-calls. Each
kernel has a pure-jnp oracle in :mod:`ref` that pytest sweeps against.
"""

from .attention import flash_attention
from .fused_head import fused_head
from .lr_step import lr_grad_step
from . import ref

__all__ = ["flash_attention", "fused_head", "lr_grad_step", "ref"]
