"""Pallas kernel: fused logistic-regression OGD weight update.

The cascade's level-1 model is a logistic regression over hashed
bag-of-words features (D = 4096). Its online update — the thing
Algorithm 1 runs after every expert annotation — is

    g  = probs - y_onehot          # [B, C], computed by fused_head
    W' = W - lr * x^T g / B        # [D, C]

The rank-C outer-product update is the memory-bound hot loop: W is the
large operand and must stream HBM→VMEM→HBM exactly once. The kernel
tiles the feature dimension D into VMEM-resident panels (grid over
D-blocks); each grid step loads one W panel and the matching x column
block, applies the fused multiply-subtract, and writes the panel back.
The gradient never materializes in HBM. ``interpret=True`` as always
(CPU PJRT cannot run Mosaic custom-calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Feature rows per W panel: 512 rows x C<=8 cols of fp32 is ~16 KiB,
# comfortably double-bufferable in VMEM alongside the x block.
DEFAULT_BLOCK_D = 512


def _lr_step_kernel(x_ref, g_ref, w_ref, lr_ref, o_ref):
    bsz = x_ref.shape[0]
    # x_blk^T @ g : [BD, C] rank-B update for this panel.
    upd = jnp.dot(
        x_ref[...].T, g_ref[...], preferred_element_type=jnp.float32
    )
    o_ref[...] = w_ref[...] - lr_ref[0] * upd / bsz


@functools.partial(jax.jit, static_argnames=("block_d",))
def lr_grad_step(x, g, w, lr, *, block_d=DEFAULT_BLOCK_D):
    """W' = W - lr * x^T g / B, tiled over the feature dimension.

    x: [B, D] f32, g: [B, C] f32 (probs - y_onehot), w: [D, C] f32,
    lr: [] f32 scalar. Returns the updated [D, C] weights.
    """
    bsz, d = x.shape
    c = w.shape[1]
    blk = min(block_d, d)
    if d % blk != 0:
        raise ValueError(f"feature dim {d} not divisible by block {blk}")
    grid = (d // blk,)
    lr_vec = jnp.reshape(lr, (1,)).astype(jnp.float32)
    return pl.pallas_call(
        _lr_step_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bsz, blk), lambda i: (0, i)),
            pl.BlockSpec((bsz, c), lambda i: (0, 0)),
            pl.BlockSpec((blk, c), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((d, c), jnp.float32),
        interpret=True,
    )(x, g, w, lr_vec)
