"""Pallas kernel: flash-style scaled-dot-product attention.

The transformer levels of the cascade (the "BERT-base / BERT-large
surrogates", DESIGN.md §3) spend their FLOPs in attention. The paper's
GPU stack gets this from fused CUDA kernels staging K/V tiles through
shared memory; the TPU re-think expresses the same HBM↔VMEM schedule as
a *K-block grid dimension with an online softmax*: the key/value
sequence is streamed in blocks, a running row-max and normalizer are
carried in VMEM scratch, and previously accumulated output is rescaled
when the max improves (Milakov–Gimelshein online softmax — the core of
FlashAttention, re-tiled for BlockSpec instead of thread blocks).

Grid: (batch*heads, num_k_blocks). Scratch persists across the K-block
dimension (the innermost, sequential grid axis), so each (head) row
tile sees K-blocks in order — exactly the double-buffered streaming
loop a TPU would pipeline.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 16
NEG_INF = -1e9


def _flash_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_ref, l_ref, acc_ref):
    kb = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # [L, Dh]
    k = k_ref[0]  # [BK, Dh]
    v = v_ref[0]  # [BK, Dh]
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [L, BK]
    s = s + (1.0 - mask_ref[...])[None, :] * NEG_INF

    m_prev = m_ref[...]  # [L, 1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # Rescale previously accumulated numerator/denominator to m_new.
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)  # [L, BK]
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kb == nk - 1)
    def _finish():
        o_ref[0] = acc_ref[...] / l_ref[...]


@functools.partial(jax.jit, static_argnames=("block_k",))
def flash_attention(q, k, v, mask, *, block_k=DEFAULT_BLOCK_K):
    """Online-softmax attention over K-blocks.

    q, k, v: [H, L, Dh] f32 (batch and heads folded by the caller),
    mask: [L] f32 key padding mask (1 = real token, 0 = pad).
    Returns [H, L, Dh] f32. L must be divisible by ``block_k``.
    """
    h, l, dh = q.shape
    blk = min(block_k, l)
    if l % blk != 0:
        raise ValueError(f"seq len {l} not divisible by K block {blk}")
    nk = l // blk
    grid = (h, nk)
    return pl.pallas_call(
        _flash_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, l, dh), lambda hh, kb: (hh, 0, 0)),
            pl.BlockSpec((1, blk, dh), lambda hh, kb: (hh, kb, 0)),
            pl.BlockSpec((1, blk, dh), lambda hh, kb: (hh, kb, 0)),
            pl.BlockSpec((blk,), lambda hh, kb: (kb,)),
        ],
        out_specs=pl.BlockSpec((1, l, dh), lambda hh, kb: (hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, l, dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((l, 1), jnp.float32),  # running row max  m_i
            pltpu.VMEM((l, 1), jnp.float32),  # running denom    l_i
            pltpu.VMEM((l, dh), jnp.float32),  # output accumulator
        ],
        interpret=True,
    )(q, k, v, mask)
