"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *correctness ground truth*: pytest (and the hypothesis
sweeps in ``python/tests/test_kernels.py``) assert that each Pallas
kernel matches its oracle to tight tolerances over randomized shapes,
dtypes, and seeds. They are also used inside the L2 *update* graphs,
where jax autodiff must flow through the computation (``pallas_call``
has no implicit VJP; see DESIGN.md §7).
"""

import jax.numpy as jnp


def softmax(x, axis=-1):
    """Numerically-stable softmax (max-subtracted)."""
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def fused_head_ref(x, w, b):
    """softmax(x @ w + b) — the classifier-head hot path.

    x: [B, D] f32, w: [D, C] f32, b: [C] f32 -> [B, C] f32
    """
    return softmax(x @ w + b[None, :])


def attention_ref(q, k, v, mask):
    """Scaled dot-product attention with a key padding mask.

    q, k, v: [H, L, Dh] f32 (heads folded with batch by the caller)
    mask:    [L] f32, 1.0 for real tokens and 0.0 for padding.
    Returns [H, L, Dh] f32.
    """
    dh = q.shape[-1]
    scores = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(dh).astype(q.dtype)
    neg = jnp.asarray(-1e9, q.dtype)
    scores = scores + (1.0 - mask)[None, None, :] * neg
    p = softmax(scores, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, v)


def lr_grad_step_ref(x, g, w, lr):
    """One fused OGD step on the logistic-regression weight matrix.

    Given the pre-computed probability-error ``g = probs - y_onehot``
    ([B, C]), applies ``w' = w - lr * x^T g / B``.

    x: [B, D], g: [B, C], w: [D, C], lr: scalar -> [D, C]
    """
    bsz = x.shape[0]
    return w - lr * (x.T @ g) / bsz


def cross_entropy_ref(probs, y_onehot, eps=1e-9):
    """Mean cross-entropy of predicted probabilities vs one-hot targets."""
    return -jnp.mean(jnp.sum(y_onehot * jnp.log(probs + eps), axis=-1))
