"""Pallas kernel: fused linear + bias + row-softmax classifier head.

This is the L1 hot-spot on the cascade's *forward* (request) path: every
level of the cascade ends in ``softmax(x @ W + b)`` — the logistic
regression model IS this kernel, and the transformer levels call it on
the pooled sequence representation.

TPU mapping (DESIGN.md §Hardware-Adaptation): the batch dimension is
tiled into VMEM-resident blocks via ``BlockSpec``; the weight panel
``[D, C]`` stays VMEM-resident across the grid (C is the label count,
2–7 here, so the panel is a thin matvec-like operand that the MXU
processes in a single pass per block). The bias-add and the
max-subtracted softmax are fused into the same block program, so logits
never round-trip to HBM. ``interpret=True`` everywhere — the CPU PJRT
plugin cannot execute Mosaic custom-calls (see /opt/xla-example/README).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows of x processed per grid step. 8 matches both the online-update
# batch size used throughout the paper's hyperparameter tables and the
# TPU fp32 sublane count.
DEFAULT_BLOCK_B = 8


def _fused_head_kernel(x_ref, w_ref, b_ref, o_ref):
    """One block: probs = softmax(x_blk @ W + b) entirely in VMEM."""
    logits = (
        jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...][None, :]
    )
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_b",))
def fused_head(x, w, b, *, block_b=DEFAULT_BLOCK_B):
    """softmax(x @ w + b) as a single fused Pallas kernel.

    x: [B, D] f32, w: [D, C] f32, b: [C] f32 -> [B, C] f32.
    B must be a multiple of ``block_b`` or smaller than it.
    """
    bsz, d = x.shape
    c = w.shape[1]
    blk = min(block_b, bsz)
    if bsz % blk != 0:
        raise ValueError(f"batch {bsz} not divisible by block {blk}")
    grid = (bsz // blk,)
    return pl.pallas_call(
        _fused_head_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((d, c), lambda i: (0, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, c), jnp.float32),
        interpret=True,
    )(x, w, b)
