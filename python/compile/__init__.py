"""Build-time Python for the OCL reproduction (L1 kernels + L2 models).

Nothing in this package is imported at runtime: ``aot.py`` lowers every
entry point to HLO text once (``make artifacts``), and the rust
coordinator executes the artifacts through PJRT.
"""
