"""Entry-point registry: every jax graph the rust runtime executes.

Each entry point is a jax function plus example argument shapes;
``aot.py`` lowers all of them to HLO text once at build time. Naming
convention: ``<model>_<op>_c<classes>_b<batch>``.

Argument convention (the contract with ``rust/src/runtime``):

    args = [data args ...] ++ [params ...] ++ [lr]   (lr: step only)
    rets = (outputs ...,)            for fwd
    rets = (params' ..., loss)       for step

The manifest records, per entry, the full arg shape/dtype list, the
index where params begin, and which parameter group (init blob) they
come from.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .models import lr, mlp, transformer

# Global dimension constants — mirrored in rust/src/config (manifest
# carries them, rust asserts agreement at load).
HASH_DIM = 4096
SEQ_LEN = 64
VOCAB = 8192
CLASS_COUNTS = (2, 7)
BATCHES_FWD = (1, 8)
BATCH_STEP = 8
ARCHS = ("base", "large")


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _param_structs(pairs):
    return [jax.ShapeDtypeStruct(a.shape, a.dtype) for _, a in pairs]


def param_groups(seed=0):
    """{group_name: [(tensor_name, np.ndarray)]} — all init blobs."""
    groups = {}
    for c in CLASS_COUNTS:
        groups[f"lr_c{c}"] = lr.init_params(HASH_DIM, c, seed)
        groups[f"mlp_c{c}"] = mlp.init_params(c, seed)
        for arch in ARCHS:
            groups[f"tfm_{arch}_c{c}"] = transformer.init_params(arch, c, seed)
    return groups


def entries():
    """{entry_name: dict(fn, args, params_at, group)} for aot.py.

    ``args`` are ShapeDtypeStructs in call order; ``params_at`` is the
    index of the first parameter argument; ``group`` names the init
    blob whose tensors occupy args[params_at : params_at+len(group)].
    """
    groups = param_groups()
    reg = {}

    def add(name, fn, data_args, group, lr_arg=False):
        params = _param_structs(groups[group])
        args = list(data_args) + params + ([_f32()] if lr_arg else [])
        reg[name] = dict(fn=fn, args=args, params_at=len(data_args), group=group)

    for c in CLASS_COUNTS:
        # --- logistic regression ---------------------------------------
        for b in BATCHES_FWD:
            add(f"lr_fwd_c{c}_b{b}", lr.forward, [_f32(b, HASH_DIM)], f"lr_c{c}")
        add(
            f"lr_step_c{c}_b{BATCH_STEP}",
            lr.step,
            [_f32(BATCH_STEP, HASH_DIM), _f32(BATCH_STEP, c)],
            f"lr_c{c}",
            lr_arg=True,
        )
        # --- transformers (BERT surrogates) -----------------------------
        for arch in ARCHS:
            fwd = transformer.make_forward(arch, c, use_pallas=True)
            stp = transformer.make_step(arch, c)
            for b in BATCHES_FWD:
                add(
                    f"tfm_{arch}_fwd_c{c}_b{b}",
                    fwd,
                    [_i32(b, SEQ_LEN), _f32(b, SEQ_LEN)],
                    f"tfm_{arch}_c{c}",
                )
            add(
                f"tfm_{arch}_step_c{c}_b{BATCH_STEP}",
                stp,
                [_i32(BATCH_STEP, SEQ_LEN), _f32(BATCH_STEP, SEQ_LEN), _f32(BATCH_STEP, c)],
                f"tfm_{arch}_c{c}",
                lr_arg=True,
            )
        # --- deferral calibration MLP ------------------------------------
        for b in BATCHES_FWD:
            add(f"mlp_fwd_c{c}_b{b}", mlp.forward, [_f32(b, c)], f"mlp_c{c}")
        add(
            f"mlp_step_c{c}_b{BATCH_STEP}",
            mlp.step,
            [_f32(BATCH_STEP, c), _f32(BATCH_STEP)],
            f"mlp_c{c}",
            lr_arg=True,
        )
    return reg


def dims():
    """Dimension block for the manifest (rust asserts agreement)."""
    return dict(
        hash_dim=HASH_DIM,
        seq_len=SEQ_LEN,
        vocab=VOCAB,
        class_counts=list(CLASS_COUNTS),
        batches_fwd=list(BATCHES_FWD),
        batch_step=BATCH_STEP,
        archs=list(ARCHS),
        mlp_hidden=mlp.HIDDEN,
        tfm_configs={a: transformer.CONFIGS[a] for a in ARCHS},
    )


__all__ = [
    "HASH_DIM", "SEQ_LEN", "VOCAB", "CLASS_COUNTS", "BATCHES_FWD",
    "BATCH_STEP", "ARCHS", "param_groups", "entries", "dims",
]

_ = np  # numpy retained for interface parity with models.*
