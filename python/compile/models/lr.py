"""L2 model: logistic regression over hashed bag-of-words features.

Level 1 of the cascade. The forward pass *is* the fused Pallas
classifier head; the online update composes the head with the fused
Pallas gradient step (analytic gradient — no autodiff needed).
"""

import jax.numpy as jnp
import numpy as np

from ..kernels import fused_head, lr_grad_step
from ..kernels import ref


def init_params(hash_dim, num_classes, seed=0):
    """Zero-initialized LR, matching the paper's from-scratch level 1.

    Returns an ordered list of (name, array) — the manifest order the
    rust runtime relies on.
    """
    del seed  # zeros: deterministic, seed kept for interface symmetry
    w = np.zeros((hash_dim, num_classes), np.float32)
    b = np.zeros((num_classes,), np.float32)
    return [("w", w), ("b", b)]


def forward(x, w, b):
    """probs = softmax(x @ w + b) via the fused Pallas head. [B,C]."""
    return (fused_head(x, w, b),)


def forward_ref(x, w, b):
    """Oracle forward (pure jnp), used in tests and inside ``step``."""
    return (ref.fused_head_ref(x, w, b),)


def step(x, y_onehot, w, b, lr):
    """One OGD step on (w, b); returns (w', b', loss).

    The W update runs through the fused Pallas ``lr_grad_step`` kernel;
    the bias update and loss are scalar-sized jnp epilogue ops.
    """
    probs = fused_head(x, w, b)
    g = probs - y_onehot
    w_new = lr_grad_step(x, g, w, lr)
    b_new = b - lr * jnp.mean(g, axis=0)
    loss = ref.cross_entropy_ref(probs, y_onehot)
    return w_new, b_new, loss


def step_ref(x, y_onehot, w, b, lr):
    """Oracle step (pure jnp) for kernel-vs-ref testing."""
    probs = ref.fused_head_ref(x, w, b)
    g = probs - y_onehot
    w_new = ref.lr_grad_step_ref(x, g, w, lr)
    b_new = b - lr * jnp.mean(g, axis=0)
    loss = ref.cross_entropy_ref(probs, y_onehot)
    return w_new, b_new, loss
