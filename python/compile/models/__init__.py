"""L2 jax models: the cascade levels and the deferral calibrator."""

from . import lr, mlp, transformer

__all__ = ["lr", "mlp", "transformer"]
