"""L2 model: transformer encoder — the "BERT-base / BERT-large surrogate".

Levels 2 (and 3, in the large cascade) of the paper's cascade are
BERT-base (110M) / BERT-large (340M). This reproduction keeps the exact
architecture *class* — token+position embeddings, pre-LN self-attention
blocks, GELU FFN, masked mean pooling, softmax classifier head — at a
size the CPU testbed can train online (DESIGN.md §3 documents why the
capacity *ladder*, not the parameter count, is what the paper's
dynamics need).

Two forward flavours:

* ``forward``      — request-path graph: attention through the Pallas
  flash kernel, head through the Pallas fused head. This is what AOT
  lowers for the rust hot path.
* ``forward_ref``  — pure-jnp twin, used (a) as the pytest oracle and
  (b) inside ``step``: the OGD update differentiates the loss with jax
  autodiff, and ``pallas_call`` carries no implicit VJP.

Parameters travel as an *ordered flat list* of (name, array): the rust
runtime treats them as opaque literals and threads the update outputs
back into the next call, so order is the only contract (manifest-
checked).
"""

import math

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import flash_attention, fused_head
from ..kernels import ref

# Architecture presets. "base" stands in for BERT-base, "large" for
# BERT-large; the c2/c3 cost constants in rust use the paper's App. C.1
# FLOP numbers so all cost accounting matches the paper exactly.
CONFIGS = {
    "base": dict(vocab=8192, seq=64, d=64, heads=4, layers=2, ffn=256),
    "large": dict(vocab=8192, seq=64, d=96, heads=6, layers=4, ffn=384),
}


def param_spec(arch, num_classes):
    """Ordered [(name, shape)] for one architecture. Manifest order."""
    cfg = CONFIGS[arch]
    v, l, d, f = cfg["vocab"], cfg["seq"], cfg["d"], cfg["ffn"]
    spec = [("embed", (v, d)), ("pos", (l, d))]
    for i in range(cfg["layers"]):
        p = f"l{i}."
        spec += [
            (p + "ln1_g", (d,)), (p + "ln1_b", (d,)),
            (p + "wq", (d, d)), (p + "bq", (d,)),
            (p + "wk", (d, d)), (p + "bk", (d,)),
            (p + "wv", (d, d)), (p + "bv", (d,)),
            (p + "wo", (d, d)), (p + "bo", (d,)),
            (p + "ln2_g", (d,)), (p + "ln2_b", (d,)),
            (p + "w1", (d, f)), (p + "b1", (f,)),
            (p + "w2", (f, d)), (p + "b2", (d,)),
        ]
    spec += [
        ("lnf_g", (d,)), ("lnf_b", (d,)),
        ("head_w", (d, num_classes)), ("head_b", (num_classes,)),
    ]
    return spec


def init_params(arch, num_classes, seed=0):
    """Deterministic init: N(0, 0.02) embeddings, Glorot dense, unit LN.

    Mirrors the BERT init recipe. Returns ordered [(name, array)].
    """
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in param_spec(arch, num_classes):
        base = name.split(".")[-1]
        if base in ("embed", "pos"):
            a = rng.normal(0.0, 0.02, shape)
        elif base.startswith("ln") and base.endswith("_g"):
            a = np.ones(shape)
        elif base.startswith("b") or base.endswith("_b"):
            a = np.zeros(shape)
        elif len(shape) == 2:
            lim = math.sqrt(6.0 / (shape[0] + shape[1]))
            a = rng.uniform(-lim, lim, shape)
        else:
            a = np.zeros(shape)
        out.append((name, a.astype(np.float32)))
    return out


def _tree(arch, num_classes, flat):
    """flat list -> {name: array}, validating count against the spec."""
    spec = param_spec(arch, num_classes)
    if len(flat) != len(spec):
        raise ValueError(f"expected {len(spec)} params, got {len(flat)}")
    return {name: p for (name, _), p in zip(spec, flat)}


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _attention_jnp(q, k, v, mask):
    return ref.attention_ref(q, k, v, mask)


def _encode_one(cfg, t, ids, mask, use_pallas):
    """Encode a single sequence: ids [L] i32, mask [L] f32 -> probs [C]."""
    l, d, h = cfg["seq"], cfg["d"], cfg["heads"]
    dh = d // h
    x = t["embed"][ids] + t["pos"]  # [L, d]
    attn_fn = flash_attention if use_pallas else _attention_jnp
    nlayers = sum(1 for name in t if name.endswith(".wq"))
    for i in range(nlayers):
        p = f"l{i}."
        hx = _layer_norm(x, t[p + "ln1_g"], t[p + "ln1_b"])
        q = (hx @ t[p + "wq"] + t[p + "bq"]).reshape(l, h, dh).transpose(1, 0, 2)
        k = (hx @ t[p + "wk"] + t[p + "bk"]).reshape(l, h, dh).transpose(1, 0, 2)
        v = (hx @ t[p + "wv"] + t[p + "bv"]).reshape(l, h, dh).transpose(1, 0, 2)
        o = attn_fn(q, k, v, mask)  # [h, L, dh]
        o = o.transpose(1, 0, 2).reshape(l, d)
        x = x + o @ t[p + "wo"] + t[p + "bo"]
        hx = _layer_norm(x, t[p + "ln2_g"], t[p + "ln2_b"])
        x = x + jax.nn.gelu(hx @ t[p + "w1"] + t[p + "b1"]) @ t[p + "w2"] + t[p + "b2"]
    x = _layer_norm(x, t["lnf_g"], t["lnf_b"])
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    pooled = jnp.sum(x * mask[:, None], axis=0) / denom  # [d]
    return pooled


def _head(pooled, t, use_pallas):
    if use_pallas:
        return fused_head(pooled, t["head_w"], t["head_b"])
    return ref.fused_head_ref(pooled, t["head_w"], t["head_b"])


def make_forward(arch, num_classes, use_pallas=True):
    """Build forward(ids [B,L] i32, mask [B,L] f32, *params) -> (probs,)."""
    cfg = CONFIGS[arch]

    def forward(ids, mask, *params):
        t = _tree(arch, num_classes, list(params))
        pooled = jax.vmap(
            lambda i1, m1: _encode_one(cfg, t, i1, m1, use_pallas)
        )(ids, mask)  # [B, d]
        probs = _head(pooled, t, use_pallas)
        return (probs,)

    return forward


def make_step(arch, num_classes):
    """Build step(ids, mask, y_onehot, *params, lr) -> (*params', loss).

    Pure-jnp forward (autodiff); SGD with gradient-norm clipping at 1.0
    for online stability (the paper trains BERT with tiny lr; clipping
    plays the same role at this scale).
    """
    fwd = make_forward(arch, num_classes, use_pallas=False)

    def loss_fn(params, ids, mask, y_onehot):
        (probs,) = fwd(ids, mask, *params)
        return ref.cross_entropy_ref(probs, y_onehot)

    def step(ids, mask, y_onehot, *rest):
        params, lr = list(rest[:-1]), rest[-1]
        loss, grads = jax.value_and_grad(loss_fn)(params, ids, mask, y_onehot)
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads) + 1e-12)
        scale = jnp.minimum(1.0, 1.0 / gnorm)
        new = [p - lr * scale * g for p, g in zip(params, grads)]
        return tuple(new) + (loss,)

    return step
