"""L2 model: deferral-calibration MLP (paper §3, "Confidence Calibration").

One MLP per non-expert cascade level. Input: the level's predictive
probability vector ``m_i(x)`` ([C]); output: a deferral score in (0,1).
Trained post-hoc by MSE against ``z_i = 1[argmax m_i(x) != y*]`` on
expert-annotated episodes only (Eq. 5). At inference the coordinator
defers when the score exceeds the level's calibration threshold
(Tables 3–4's "Calibration Factor").

The probability vector is augmented with two sufficient statistics the
paper's confidence-deferral discussion leans on — max-probability and
normalized entropy — computed *inside* the graph so rust feeds raw
probabilities only.
"""

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ref

HIDDEN = 16


def input_dim(num_classes):
    return num_classes + 2  # probs ++ [maxprob, normalized entropy]


def param_spec(num_classes):
    i = input_dim(num_classes)
    return [
        ("w1", (i, HIDDEN)), ("b1", (HIDDEN,)),
        ("w2", (HIDDEN, 1)), ("b2", (1,)),
    ]


def init_params(num_classes, seed=0):
    """Glorot weights, zero hidden bias, and **+1 output bias**: the
    initial deferral score is sigmoid(≈1) ≈ 0.73, above every
    calibration threshold in the paper's tables — the cascade starts
    with its gates open (paper §1: "At startup, the policy keeps its
    gates open, allowing all initial inputs to flow through the cascade
    and be processed by the most expensive model").
    """
    rng = np.random.default_rng(seed + 17)
    out = []
    for name, shape in param_spec(num_classes):
        if name.startswith("w"):
            lim = np.sqrt(6.0 / (shape[0] + shape[1]))
            a = rng.uniform(-lim, lim, shape)
        elif name == "b2":
            a = np.ones(shape)
        else:
            a = np.zeros(shape)
        out.append((name, a.astype(np.float32)))
    return out


def _features(probs):
    """[B, C] probs -> [B, C+2] with maxprob and normalized entropy."""
    c = probs.shape[-1]
    eps = 1e-9
    ent = -jnp.sum(probs * jnp.log(probs + eps), axis=-1, keepdims=True)
    ent = ent / jnp.log(jnp.asarray(float(c)))
    mx = jnp.max(probs, axis=-1, keepdims=True)
    return jnp.concatenate([probs, mx, ent], axis=-1)


def forward(probs, w1, b1, w2, b2):
    """Deferral score per row: sigmoid MLP over calibrated features."""
    h = jnp.tanh(_features(probs) @ w1 + b1)
    score = jax.nn.sigmoid(h @ w2 + b2)  # [B, 1]
    return (score[:, 0],)


def step(probs, z, w1, b1, w2, b2, lr):
    """One OGD step on the MSE objective (Eq. 5); returns params + loss."""

    def loss_fn(params):
        (score,) = forward(probs, *params)
        return jnp.mean((score - z) ** 2)

    loss, grads = jax.value_and_grad(loss_fn)([w1, b1, w2, b2])
    new = [p - lr * g for p, g in zip([w1, b1, w2, b2], grads)]
    return tuple(new) + (loss,)


def forward_ref(probs, w1, b1, w2, b2):
    """Alias — the MLP forward is already pure jnp (no Pallas here)."""
    return forward(probs, w1, b1, w2, b2)


__all__ = [
    "HIDDEN", "input_dim", "param_spec", "init_params",
    "forward", "forward_ref", "step",
]

# keep linters honest about the ref import being intentional
_ = ref
